"""End-to-end training driver (deliverable b): train a small LM for a few
hundred steps with checkpoint/restart, then sample from it.

Defaults to a ~10M-param qwen3-family model that runs on CPU in minutes;
``--arch <id> --full-width`` scales to ~100M+ (same code path; on real
hardware add the mesh flags).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm, serving
from repro.trainer.loop import run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--full-width", action="store_true",
                    help="~100M params instead of the CPU-friendly ~10M")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.full_width:
        cfg = cfg.reduced(d_model=768, n_layers=12, n_heads=12,
                          n_kv_heads=4, d_ff=2048, vocab=32000)
    else:
        cfg = cfg.reduced(d_model=256, n_layers=4, n_heads=4, n_kv_heads=2,
                          d_ff=683, vocab=4096)
    n_params = cfg.param_count()
    print(f"training {cfg.name} reduced: ~{n_params / 1e6:.1f}M params")

    params, _, history = run_training(
        cfg, args.workdir, args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, lr=1e-3, ckpt_every=100)
    losses = [l for _, l in history]
    print(f"loss: start {losses[0]:.3f} → end {losses[-1]:.3f} "
          f"(best {min(losses):.3f})")
    assert losses[-1] < losses[0], "loss must decrease"

    # greedy decode a few tokens through the serving path
    tokens = jnp.zeros((1, 8), jnp.int32)
    logits, cache, pos = serving.prefill(params, cfg, tokens)
    cache = jax.tree.map(
        lambda a: (jnp.pad(a, [(0, 0), (0, 0), (0, 24)] + [(0, 0)] *
                           (a.ndim - 3))
                   if a.ndim >= 4 and a.shape[2] == 8 else a), cache)
    out = []
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(16):
        out.append(int(tok[0, 0]))
        logits, cache = serving.decode_step(params, cfg, cache, tok, pos)
        tok = jnp.argmax(logits, -1)[:, None]
        pos = pos + 1
    print("greedy sample token ids:", out)


if __name__ == "__main__":
    main()
