"""Barnes-Hut N-body through QuickSched (paper §4.2): octree, hierarchical
resource conflicts, COM dependency tree, accuracy vs direct summation.

    PYTHONPATH=src python examples/nbody.py [n_particles]
"""

import sys
import time

import numpy as np

from repro.apps import barneshut as bh
from repro.core import simulate
from repro.kernels.nbody import ref

n = int(sys.argv[1]) if len(sys.argv) > 1 else 20_000
rng = np.random.default_rng(0)
x = rng.random((n, 3))
m = rng.random(n) + 0.5

t0 = time.time()
acc, state, graph = bh.solve(x, m, n_max=64, n_task=1000, backend="pallas")
print(f"N={n}: solved in {time.time() - t0:.1f}s; "
      f"tasks={graph.counts['tasks']} "
      f"(self={graph.counts['self']} pair={graph.counts['pair_pp']} "
      f"pc={graph.counts['pair_pc']} com={graph.counts['com']})")

# accuracy vs O(N^2) direct sum on a subsample
sub = min(n, 2000)
exact = ref.acc_direct_ref(state.x[:, :], state.m)
import numpy as _np
rel = (_np.linalg.norm(_np.asarray(acc - exact), axis=0)
       / _np.maximum(_np.linalg.norm(_np.asarray(exact), axis=0), 1e-12))
print(f"median relative force error vs direct sum: {float(_np.median(rel)):.2e}")

# simulated strong scaling (paper Fig 11)
for workers in (1, 8, 32, 64):
    tree = bh.Octree(x, m, n_max=64)
    g = bh.build_graph(tree, n_task=1000, nr_queues=workers)
    r = simulate(g.sched, workers)
    print(f"  {workers:3d} workers: efficiency "
          f"{r.total_cost / (workers * r.makespan):.2%}")
