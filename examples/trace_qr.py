"""Predicted-vs-measured QR task timelines in one Perfetto view.

The paper's evaluation figures are per-thread task timelines (Figs 6/7)
plus scheduler-overhead accounting.  This demo reproduces that artifact
end to end with the observability tier (DESIGN.md §Observability):

1. build the tiled-QR task graph and lower it through the plan + engine
   table pipeline (the tracer records the build/prepare/lower/encode
   spans along the way);
2. measure every engine work item with ``measure_round_times
   (per_item=True)`` — the paper's per-task tic/toc, recorded as task
   events on the **measured** process track;
3. replay the measured item costs through the discrete-event simulator
   at ``--lanes`` workers and emit its timeline as the **predicted**
   process track, aligned to the measured clock;
4. export both tracks plus the metrics snapshot as Chrome trace-event
   JSON — drag it into https://ui.perfetto.dev (or chrome://tracing).

    PYTHONPATH=src python examples/trace_qr.py --out /tmp/trace_qr.json
"""

from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=96, help="matrix size")
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--lanes", type=int, default=4,
                    help="simulated workers for the predicted track")
    ap.add_argument("--out", default="trace_qr.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro import engine
    from repro.apps import qr
    from repro.core import lower
    from repro.core.simulator import replay_item_times, timeline_to_tracer
    from repro.obs import enable, get_registry, write_chrome_trace

    tracer = enable(process="measured")

    a = jnp.asarray(np.random.default_rng(args.seed)
                    .standard_normal((args.n, args.n)), jnp.float32)
    tiles, mt, nt = qr._split_tiles(a, args.tile)
    sched, _ = qr.make_qr_graph(mt, nt, nr_queues=args.lanes)
    plan = lower(sched, args.lanes)
    state = qr._TileState(dict(tiles), "pallas")
    tables = engine.lower_tables(
        plan, sched, state.batch_registry(),
        arg_width=engine.QR_ARG_WIDTH, row_access=engine.qr_row_access)
    stack = jnp.stack([tiles[i, j] for j in range(nt) for i in range(mt)])

    # measured: one task record per engine work item (paper tic/toc)
    timings = engine.measure_round_times(
        tables, engine.qr_round_fn(), (), (stack, jnp.zeros_like(stack)),
        per_item=True)

    # predicted: replay the measured per-item costs through the
    # discrete-event model at --lanes workers, on the measured clock
    result = replay_item_times(sched, tables.tids, timings.item_s,
                               nr_workers=args.lanes)
    t_origin = min(t.t0 for t in tracer.tasks)
    n_pred = timeline_to_tracer(result, process="predicted",
                                t_origin=t_origin)

    names = {qr.T_GEQRF: "GEQRF", qr.T_LARFT: "LARFT",
             qr.T_TSQRF: "TSQRF", qr.T_SSRFT: "SSRFT"}
    info = write_chrome_trace(args.out, registry=get_registry(),
                              type_names=names)
    measured_s = float(timings.item_s.sum())
    print(f"qr {args.n}x{args.n} tile {args.tile}: {sched.nr_tasks} tasks, "
          f"{tables.nr_items} items")
    print(f"measured serial {measured_s * 1e3:.1f}ms; predicted "
          f"{args.lanes}-lane makespan {result.makespan * 1e3:.1f}ms "
          f"(speedup {measured_s / result.makespan:.2f}x, "
          f"{n_pred} predicted events)")
    print(f"trace: {args.out} ({info['events']} events, processes="
          f"{info['processes']}) — open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
