"""Quickstart: QuickSched in ~70 lines — build a task graph with
dependencies AND conflicts, run it four ways (including the
device-resident engine).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.apps import qr
from repro.core import QSched, SequentialExecutor, simulate

# --- 1. the paper's Figure 2 graph: dependencies + a conflict ----------------
s = QSched(nr_queues=2)
shared = s.addres()                      # the conflict: a shared resource
a = s.addtask(data="A", cost=1.0)
b = s.addtask(data="B", cost=1.0)
c = s.addtask(data="C", cost=1.0)
for t in (b, c):
    s.addunlock(a, t)                    # B, C depend on A
    s.addlock(t, shared)                 # B, C conflict (any order, not together)

order = []
SequentialExecutor(s).run(lambda ty, data: order.append(data))
print("execution order:", order)

res = simulate(s, nr_workers=2)
print(f"2 workers, makespan={res.makespan} "
      f"(B and C serialized by the conflict)")

# --- 2. something real: tiled QR through the scheduler ------------------------
a_mat = jnp.asarray(np.random.default_rng(0).standard_normal((96, 96)),
                    jnp.float32)
r, sched = qr.run_qr(a_mat, tile=32, mode="sequential", backend="pallas")
gram_err = float(jnp.max(jnp.abs(r.T @ r - a_mat.T @ a_mat)))
print(f"tiled QR via QuickSched: {sched.nr_tasks} tasks, "
      f"|R^T R - A^T A| = {gram_err:.2e}")

# --- 3. the same QR on the device-resident engine ----------------------------
# The plan lowers to descriptor task tables and the whole factorization
# executes as ONE jitted dispatch of fused type-branching Pallas rounds
# (DESIGN.md §Engine) — vs one host dispatch per task/batch per round.
r_eng, _ = qr.run_qr(a_mat, tile=32, mode="engine")
host, eng = qr.dispatch_counts(a_mat, tile=32)
print(f"engine mode: |R_engine - R| = "
      f"{float(jnp.max(jnp.abs(r_eng - r))):.2e}; "
      f"host dispatches {host} -> {eng} ({host / eng:.0f}x fewer)")

# --- 4. strong scaling of the same graph (simulated workers) ----------------
for n in (1, 4, 16, 64):
    s2, _ = qr.make_qr_graph(16, 16, nr_queues=n)
    r2 = simulate(s2, n)
    print(f"  {n:3d} workers: simulated speedup "
          f"{simulate(qr.make_qr_graph(16, 16, nr_queues=1)[0], 1).makespan / r2.makespan:6.2f}")
