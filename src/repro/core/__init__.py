"""QuickSched core: task-based parallelism with dependencies and conflicts.

Faithful JAX-era port of the paper's scheduler (see DESIGN.md §2 for the
CPU→TPU adaptation map).
"""

from .graph import (
    FLAG_NONE,
    FLAG_VIRTUAL,
    OWNER_NONE,
    RES_NONE,
    TASK_NONE,
    QSched,
    Resource,
    Task,
)
from .arrays import CompiledGraph
from .locks import SeqLockManager, ThreadedLockManager, make_lock_manager
from .plan import (BatchSpec, ExecutionPlan, PlanRound, TypedBatch,
                   clear_plan_cache, color_phases, lower, plan_cache_info)
from .queue import TaskQueue
from .simulator import (SimResult, TimelineEvent, replay_item_times,
                        replay_round_times, scaling_curve, simulate)
from .static_sched import Round, conflict_rounds, list_schedule, validate_rounds
from .weights import critical_path_length, critical_path_weights, toposort
from .executors import SequentialExecutor, ThreadedExecutor, registry_fun
from .backends import (Backend, BackendUnsupported, EngineHooks,
                       available_backends, get_backend, register_backend,
                       run_plan)

__all__ = [
    "QSched", "Task", "Resource", "TaskQueue", "CompiledGraph",
    "FLAG_NONE", "FLAG_VIRTUAL", "TASK_NONE", "RES_NONE", "OWNER_NONE",
    "SeqLockManager", "ThreadedLockManager", "make_lock_manager",
    "SimResult", "TimelineEvent", "simulate", "scaling_curve",
    "replay_round_times", "replay_item_times",
    "Round", "conflict_rounds", "validate_rounds", "list_schedule",
    "BatchSpec", "ExecutionPlan", "PlanRound", "TypedBatch",
    "lower", "clear_plan_cache", "color_phases", "plan_cache_info",
    "toposort", "critical_path_weights", "critical_path_length",
    "SequentialExecutor", "ThreadedExecutor", "registry_fun",
    "Backend", "BackendUnsupported", "EngineHooks",
    "get_backend", "register_backend", "available_backends", "run_plan",
]
