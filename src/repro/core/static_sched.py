"""Static conflict-aware schedules for SPMD execution.

On a TPU there is no runtime lock — the compiled program is bulk
synchronous.  The QuickSched insight (the whole DAG is known up front)
becomes: *prove at schedule time* that no two conflicting tasks overlap.

``conflict_rounds`` partitions the task graph into rounds: every task in a
round has all dependencies in strictly earlier rounds, and no two tasks in a
round lock overlapping resource subtrees.  Each round then executes as one
SPMD step (every mesh lane runs its assigned tasks); inter-round data motion
is explicit.  Task → lane assignment inside a round follows resource
ownership (the cache-affinity analogue) with greedy load balancing
(the work-stealing analogue).

``list_schedule`` wraps the discrete-event simulator to produce a
worker-timed schedule (used for pipeline-parallel synthesis, where stage
lanes are the workers).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from .graph import QSched
from .locks import SeqLockManager
from .simulator import SimResult, simulate


@dataclass
class Round:
    tasks: List[int]               # task ids in this round
    lanes: Dict[int, List[int]]    # lane -> ordered task ids


def conflict_rounds(sched: QSched, nr_lanes: int,
                    max_tasks_per_round: Optional[int] = None) -> List[Round]:
    """Thin compatibility wrapper over the shared ``plan.lower`` lowering,
    returning the legacy ``Round`` shape.  Rounds satisfy the same
    invariants (``validate_rounds``) as the pre-refactor implementation;
    on graphs with intra-level conflicts the exact packing can differ in
    weight-tie order (newly released tasks enter the ready set in
    ascending-id order)."""
    from .plan import lower

    plan = lower(sched, nr_lanes, max_tasks_per_round)
    return [Round(list(rnd.tids),
                  {l: list(tids) for l, tids in enumerate(rnd.lanes)})
            for rnd in plan.rounds]


def validate_rounds(sched: QSched, rounds: List[Round]) -> None:
    """Dependencies strictly cross rounds; conflicts never share a round."""
    pos = {}
    for k, rnd in enumerate(rounds):
        for tid in rnd.tasks:
            assert tid not in pos, f"task {tid} scheduled twice"
            pos[tid] = k
    assert len(pos) == sched.nr_tasks, "missing tasks in rounds"
    for t in sched.tasks:
        for j in t.unlocks:
            assert pos[j] > pos[t.tid], f"dep {t.tid}->{j} within/behind round"
    parents = [r.parent for r in sched.resources]
    for rnd in rounds:
        lm = SeqLockManager(parents)
        for tid in rnd.tasks:
            assert lm.lock_all(sched.tasks[tid].locks), (
                f"conflicting tasks share round: {rnd.tasks}")


def list_schedule(sched: QSched, nr_workers: int) -> SimResult:
    """Worker-timed static schedule via the discrete-event engine."""
    return simulate(sched, nr_workers)
