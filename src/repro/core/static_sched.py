"""Static conflict-aware schedules for SPMD execution.

On a TPU there is no runtime lock — the compiled program is bulk
synchronous.  The QuickSched insight (the whole DAG is known up front)
becomes: *prove at schedule time* that no two conflicting tasks overlap.

``conflict_rounds`` partitions the task graph into rounds: every task in a
round has all dependencies in strictly earlier rounds, and no two tasks in a
round lock overlapping resource subtrees.  Each round then executes as one
SPMD step (every mesh lane runs its assigned tasks); inter-round data motion
is explicit.  Task → lane assignment inside a round follows resource
ownership (the cache-affinity analogue) with greedy load balancing
(the work-stealing analogue).

``list_schedule`` wraps the discrete-event simulator to produce a
worker-timed schedule (used for pipeline-parallel synthesis, where stage
lanes are the workers).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from .graph import OWNER_NONE, QSched
from .locks import SeqLockManager
from .simulator import SimResult, simulate


@dataclass
class Round:
    tasks: List[int]               # task ids in this round
    lanes: Dict[int, List[int]]    # lane -> ordered task ids


def conflict_rounds(sched: QSched, nr_lanes: int,
                    max_tasks_per_round: Optional[int] = None) -> List[Round]:
    if not sched._prepared:
        sched.prepare()
    tasks = sched.tasks
    n = len(tasks)
    cap = max_tasks_per_round or n
    wait = [0] * n
    for t in tasks:
        for j in t.unlocks:
            wait[j] += 1
    ready = sorted((i for i in range(n) if wait[i] == 0),
                   key=lambda i: -tasks[i].weight)
    parents = [r.parent for r in sched.resources]
    owners = [r.owner for r in sched.resources]
    rounds: List[Round] = []
    done = 0
    while done < n:
        lm = SeqLockManager(parents)  # fresh lock state per round
        chosen: List[int] = []
        skipped: List[int] = []
        for tid in ready:
            if len(chosen) >= cap:
                skipped.append(tid)
                continue
            if lm.lock_all(tasks[tid].locks):
                chosen.append(tid)
            else:
                skipped.append(tid)
        if not chosen:
            raise RuntimeError("static schedule stalled (conflict deadlock?)")
        # lane assignment: prefer the owner of the task's first owned
        # resource; spill to the least-loaded lane.
        load = [0.0] * nr_lanes
        lanes: Dict[int, List[int]] = {l: [] for l in range(nr_lanes)}
        for tid in sorted(chosen, key=lambda i: -tasks[i].weight):
            lane = -1
            for r in tasks[tid].locks + tasks[tid].uses:
                o = owners[r]
                if o != OWNER_NONE and 0 <= o < nr_lanes:
                    lane = o
                    break
            least = min(range(nr_lanes), key=lambda l: load[l])
            if lane == -1 or load[lane] > 2.0 * max(load[least], 1e-12) + 1e-12:
                lane = least  # steal: owner lane overloaded
            lanes[lane].append(tid)
            load[lane] += tasks[tid].cost
            for r in tasks[tid].locks + tasks[tid].uses:
                owners[r] = lane
        rounds.append(Round(chosen, lanes))
        done += len(chosen)
        # release deps
        newly = []
        for tid in chosen:
            for j in tasks[tid].unlocks:
                wait[j] -= 1
                if wait[j] == 0:
                    newly.append(j)
        ready = sorted(skipped + newly, key=lambda i: -tasks[i].weight)
    return rounds


def validate_rounds(sched: QSched, rounds: List[Round]) -> None:
    """Dependencies strictly cross rounds; conflicts never share a round."""
    pos = {}
    for k, rnd in enumerate(rounds):
        for tid in rnd.tasks:
            assert tid not in pos, f"task {tid} scheduled twice"
            pos[tid] = k
    assert len(pos) == sched.nr_tasks, "missing tasks in rounds"
    for t in sched.tasks:
        for j in t.unlocks:
            assert pos[j] > pos[t.tid], f"dep {t.tid}->{j} within/behind round"
    parents = [r.parent for r in sched.resources]
    for rnd in rounds:
        lm = SeqLockManager(parents)
        for tid in rnd.tasks:
            assert lm.lock_all(sched.tasks[tid].locks), (
                f"conflicting tasks share round: {rnd.tasks}")


def list_schedule(sched: QSched, nr_workers: int) -> SimResult:
    """Worker-timed static schedule via the discrete-event engine."""
    return simulate(sched, nr_workers)
