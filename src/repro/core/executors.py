"""Host-side executors for a prepared QSched graph.

* ``ThreadedExecutor`` — the paper's pthreads worker pool: one queue per
  thread, spin(-ish) on gettask, execute, done.  Exercises the *threaded*
  lock protocol (real mutex-emulated CAS).  Python's GIL serialises compute,
  so this validates correctness, not speedup.
* ``SequentialExecutor`` — a single worker draining the scheduler in
  priority order; used to trace task bodies into a single jitted function
  (tasks execute as jnp ops on traced values).

Both accept ``pass_tid=True`` to call ``fun(type, data, tid)`` for task
bodies that key side tables by task id (Barnes-Hut's per-task work lists).

Observability (DESIGN.md §Observability): when the global tracer is
enabled, both executors record one per-task tic/toc record
``(tid, type, worker, t0, t1)`` — the paper's per-thread task timelines
(Figs 6/7/11/12).  Independently of tracing, each run tallies exact
per-type execution counts (``type_counts``) and, for the threaded
executor, the failed ``lockres`` attempts of the run (``lock_failures``,
the paper's Fig 13 overhead accounting) — both also bulk-incremented
onto the global metrics registry (``executor.tasks.type*``,
``executor.tasks_executed``, ``executor.lock_failures``).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Mapping

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

from .graph import FLAG_VIRTUAL, QSched


def registry_fun(registry: Mapping[int, Any]) -> Callable[[int, Any, int], None]:
    """Adapt a BatchSpec registry into the ``fun(type, data, tid)`` shape
    the executors call: each task dispatches to its type's ``run_one``.
    This is how the sequential/threaded backends share the exact same
    per-type task bodies as the rounds/engine paths (core.backends)."""
    def fun(ttype: int, data: Any, tid: int) -> None:
        spec = registry.get(ttype)
        if spec is None:
            raise KeyError(f"no BatchSpec registered for task type {ttype}")
        spec.run_one(tid, data)
    return fun


def _publish_counts(prefix: str, type_counts: Dict[int, int],
                    lock_failures: int = 0) -> None:
    """Bulk-increment one run's exact tallies onto the global registry
    (one ``inc`` per type, never per task — zero hot-path cost)."""
    reg = _metrics.get_registry()
    total = 0
    for ttype, n in type_counts.items():
        reg.counter(f"{prefix}.tasks.type{ttype}").inc(n)
        total += n
    reg.counter(f"{prefix}.tasks_executed").inc(total)
    if lock_failures:
        reg.counter(f"{prefix}.lock_failures").inc(lock_failures)


class ThreadedExecutor:
    def __init__(self, sched: QSched, nr_threads: int):
        self.sched = sched
        self.nr_threads = nr_threads
        self.errors: List[BaseException] = []
        self._abort = threading.Event()
        # per-run accounting, reset by run() like the error state
        self.lock_failures = 0
        self.type_counts: Dict[int, int] = {}
        self._worker_counts: List[Dict[int, int]] = []

    def _worker(self, wid: int, fun: Callable[..., None],
                pass_tid: bool) -> None:
        s = self.sched
        qid = wid % s.nr_queues
        ttype, tdata, tflags = s._ttype, s._tdata, s._tflags
        tr = _trace.get_tracer()
        counts = self._worker_counts[wid]
        try:
            while not self._abort.is_set():
                tid = s.gettask(qid, block=False)
                if tid is None:
                    if s.waiting <= 0:
                        return
                    time.sleep(1e-5)  # qsched_flag_yield analogue
                    continue
                if not tflags[tid] & FLAG_VIRTUAL:
                    tt = ttype[tid]
                    if tr.enabled:
                        t0 = time.perf_counter()
                    if pass_tid:
                        fun(tt, tdata[tid], tid)
                    else:
                        fun(tt, tdata[tid])
                    if tr.enabled:
                        tr.task(tid, tt, wid, t0, time.perf_counter())
                    counts[tt] = counts.get(tt, 0) + 1
                s.done(tid)
        except BaseException as e:  # surface worker errors to the caller
            self.errors.append(e)
            # A failed task never reaches done(), so `waiting` can never
            # drain — without this abort the surviving workers would spin
            # forever and run() would hang in join instead of raising.
            self._abort.set()

    def run(self, fun: Callable[..., None], pass_tid: bool = False) -> None:
        self.errors.clear()
        self._abort.clear()
        self.lock_failures = 0
        self.type_counts = {}
        self._worker_counts = [{} for _ in range(self.nr_threads)]
        self.sched.start(threaded=True)
        threads = [
            threading.Thread(target=self._worker, args=(w, fun, pass_tid),
                             daemon=True)
            for w in range(self.nr_threads)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        # workers have quiesced: merge their private tallies (exact, no
        # cross-thread increments anywhere on the hot path)
        for counts in self._worker_counts:
            for tt, n in counts.items():
                self.type_counts[tt] = self.type_counts.get(tt, 0) + n
        self.lock_failures = self.sched.lock_failures
        _publish_counts("executor", self.type_counts, self.lock_failures)
        if self.errors:
            raise self.errors[0]
        if self.sched.waiting > 0:
            raise RuntimeError(
                f"{self.sched.waiting} tasks unexecuted (deadlock?)")
        assert self.sched.lockmgr.all_free(), "resources left locked"

    def run_registry(self, registry: Mapping[int, Any]) -> None:
        """Drain the scheduler dispatching each task to its type's
        ``BatchSpec.run_one`` (the backend-registry entry point)."""
        self.run(registry_fun(registry), pass_tid=True)


class SequentialExecutor:
    """Drain the scheduler with one worker.  Because tasks run in the
    scheduler's priority order and ``fun`` may operate on traced JAX values,
    wrapping ``run`` in ``jax.jit`` turns the whole task graph into a single
    XLA program whose op order follows the QuickSched schedule.

    Per-task tic/toc records measure *host dispatch* time here — under
    ``jax.jit`` the bodies trace rather than execute, so the records show
    scheduling order, not device time."""

    def __init__(self, sched: QSched):
        self.sched = sched
        self.type_counts: Dict[int, int] = {}

    def run(self, fun: Callable[..., None],
            pass_tid: bool = False) -> List[int]:
        s = self.sched
        s.start(threaded=False)
        ttype, tdata, tflags = s._ttype, s._tdata, s._tflags
        tr = _trace.get_tracer()
        counts: Dict[int, int] = {}
        order: List[int] = []
        while True:
            tid = s.gettask(0, block=False)
            if tid is None:
                if s.waiting <= 0:
                    break
                raise RuntimeError(
                    f"no runnable task with {s.waiting} waiting (deadlock)")
            if not tflags[tid] & FLAG_VIRTUAL:
                tt = ttype[tid]
                if tr.enabled:
                    t0 = time.perf_counter()
                if pass_tid:
                    fun(tt, tdata[tid], tid)
                else:
                    fun(tt, tdata[tid])
                if tr.enabled:
                    tr.task(tid, tt, 0, t0, time.perf_counter())
                counts[tt] = counts.get(tt, 0) + 1
            order.append(tid)
            s.done(tid)
        self.type_counts = counts
        _publish_counts("executor", counts)
        return order

    def run_registry(self, registry: Mapping[int, Any]) -> List[int]:
        """Drain the scheduler dispatching each task to its type's
        ``BatchSpec.run_one`` (the backend-registry entry point)."""
        return self.run(registry_fun(registry), pass_tid=True)
