"""Host-side executors for a prepared QSched graph.

* ``ThreadedExecutor`` — the paper's pthreads worker pool: one queue per
  thread, spin(-ish) on gettask, execute, done.  Exercises the *threaded*
  lock protocol (real mutex-emulated CAS).  Python's GIL serialises compute,
  so this validates correctness, not speedup.
* ``SequentialExecutor`` — a single worker draining the scheduler in
  priority order; used to trace task bodies into a single jitted function
  (tasks execute as jnp ops on traced values).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, List

from .graph import FLAG_VIRTUAL, QSched


class ThreadedExecutor:
    def __init__(self, sched: QSched, nr_threads: int):
        self.sched = sched
        self.nr_threads = nr_threads
        self.errors: List[BaseException] = []

    def _worker(self, wid: int, fun: Callable[[int, Any], None]) -> None:
        s = self.sched
        qid = wid % s.nr_queues
        try:
            while True:
                tid = s.gettask(qid, block=False)
                if tid is None:
                    if s.waiting <= 0:
                        return
                    time.sleep(1e-5)  # qsched_flag_yield analogue
                    continue
                t = s.tasks[tid]
                if not (t.flags & FLAG_VIRTUAL):
                    fun(t.type, t.data)
                s.done(tid)
        except BaseException as e:  # surface worker errors to the caller
            self.errors.append(e)

    def run(self, fun: Callable[[int, Any], None]) -> None:
        self.sched.start(threaded=True)
        threads = [
            threading.Thread(target=self._worker, args=(w, fun), daemon=True)
            for w in range(self.nr_threads)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if self.errors:
            raise self.errors[0]
        if self.sched.waiting > 0:
            raise RuntimeError(
                f"{self.sched.waiting} tasks unexecuted (deadlock?)")
        assert self.sched.lockmgr.all_free(), "resources left locked"


class SequentialExecutor:
    """Drain the scheduler with one worker.  Because tasks run in the
    scheduler's priority order and ``fun`` may operate on traced JAX values,
    wrapping ``run`` in ``jax.jit`` turns the whole task graph into a single
    XLA program whose op order follows the QuickSched schedule."""

    def __init__(self, sched: QSched):
        self.sched = sched

    def run(self, fun: Callable[[int, Any], None]) -> List[int]:
        s = self.sched
        s.start(threaded=False)
        order: List[int] = []
        while True:
            tid = s.gettask(0, block=False)
            if tid is None:
                if s.waiting <= 0:
                    break
                raise RuntimeError(
                    f"no runnable task with {s.waiting} waiting (deadlock)")
            t = s.tasks[tid]
            if not (t.flags & FLAG_VIRTUAL):
                fun(t.type, t.data)
            order.append(tid)
            s.done(tid)
        return order
