"""Host-side executors for a prepared QSched graph.

* ``ThreadedExecutor`` — the paper's pthreads worker pool: one queue per
  thread, spin(-ish) on gettask, execute, done.  Exercises the *threaded*
  lock protocol (real mutex-emulated CAS).  Python's GIL serialises compute,
  so this validates correctness, not speedup.
* ``SequentialExecutor`` — a single worker draining the scheduler in
  priority order; used to trace task bodies into a single jitted function
  (tasks execute as jnp ops on traced values).

Both accept ``pass_tid=True`` to call ``fun(type, data, tid)`` for task
bodies that key side tables by task id (Barnes-Hut's per-task work lists).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, List, Mapping

from .graph import FLAG_VIRTUAL, QSched


def registry_fun(registry: Mapping[int, Any]) -> Callable[[int, Any, int], None]:
    """Adapt a BatchSpec registry into the ``fun(type, data, tid)`` shape
    the executors call: each task dispatches to its type's ``run_one``.
    This is how the sequential/threaded backends share the exact same
    per-type task bodies as the rounds/engine paths (core.backends)."""
    def fun(ttype: int, data: Any, tid: int) -> None:
        spec = registry.get(ttype)
        if spec is None:
            raise KeyError(f"no BatchSpec registered for task type {ttype}")
        spec.run_one(tid, data)
    return fun


class ThreadedExecutor:
    def __init__(self, sched: QSched, nr_threads: int):
        self.sched = sched
        self.nr_threads = nr_threads
        self.errors: List[BaseException] = []
        self._abort = threading.Event()

    def _worker(self, wid: int, fun: Callable[..., None],
                pass_tid: bool) -> None:
        s = self.sched
        qid = wid % s.nr_queues
        ttype, tdata, tflags = s._ttype, s._tdata, s._tflags
        try:
            while not self._abort.is_set():
                tid = s.gettask(qid, block=False)
                if tid is None:
                    if s.waiting <= 0:
                        return
                    time.sleep(1e-5)  # qsched_flag_yield analogue
                    continue
                if not tflags[tid] & FLAG_VIRTUAL:
                    if pass_tid:
                        fun(ttype[tid], tdata[tid], tid)
                    else:
                        fun(ttype[tid], tdata[tid])
                s.done(tid)
        except BaseException as e:  # surface worker errors to the caller
            self.errors.append(e)
            # A failed task never reaches done(), so `waiting` can never
            # drain — without this abort the surviving workers would spin
            # forever and run() would hang in join instead of raising.
            self._abort.set()

    def run(self, fun: Callable[..., None], pass_tid: bool = False) -> None:
        self.errors.clear()
        self._abort.clear()
        self.sched.start(threaded=True)
        threads = [
            threading.Thread(target=self._worker, args=(w, fun, pass_tid),
                             daemon=True)
            for w in range(self.nr_threads)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if self.errors:
            raise self.errors[0]
        if self.sched.waiting > 0:
            raise RuntimeError(
                f"{self.sched.waiting} tasks unexecuted (deadlock?)")
        assert self.sched.lockmgr.all_free(), "resources left locked"

    def run_registry(self, registry: Mapping[int, Any]) -> None:
        """Drain the scheduler dispatching each task to its type's
        ``BatchSpec.run_one`` (the backend-registry entry point)."""
        self.run(registry_fun(registry), pass_tid=True)


class SequentialExecutor:
    """Drain the scheduler with one worker.  Because tasks run in the
    scheduler's priority order and ``fun`` may operate on traced JAX values,
    wrapping ``run`` in ``jax.jit`` turns the whole task graph into a single
    XLA program whose op order follows the QuickSched schedule."""

    def __init__(self, sched: QSched):
        self.sched = sched

    def run(self, fun: Callable[..., None],
            pass_tid: bool = False) -> List[int]:
        s = self.sched
        s.start(threaded=False)
        ttype, tdata, tflags = s._ttype, s._tdata, s._tflags
        order: List[int] = []
        while True:
            tid = s.gettask(0, block=False)
            if tid is None:
                if s.waiting <= 0:
                    break
                raise RuntimeError(
                    f"no runnable task with {s.waiting} waiting (deadlock)")
            if not tflags[tid] & FLAG_VIRTUAL:
                if pass_tid:
                    fun(ttype[tid], tdata[tid], tid)
                else:
                    fun(ttype[tid], tdata[tid])
            order.append(tid)
            s.done(tid)
        return order

    def run_registry(self, registry: Mapping[int, Any]) -> List[int]:
        """Drain the scheduler dispatching each task to its type's
        ``BatchSpec.run_one`` (the backend-registry entry point)."""
        return self.run(registry_fun(registry), pass_tid=True)
