"""The qsched object: tasks, resources, dependencies, conflicts (paper §3.1–3.4).

The full task graph is constructed explicitly *before* execution
(``addtask`` / ``addres`` / ``addlock`` / ``adduse`` / ``addunlock``), then
``prepare()`` computes wait counters and critical-path weights.  Execution
engines (simulator, threaded executor, static scheduler, ExecutionPlan)
drive the same ``start`` / ``gettask`` / ``done`` protocol.

Storage is array-native: graph construction appends to flat scalar/COO
lists (no per-task objects), and ``prepare()`` compiles them into the CSR
``CompiledGraph`` (see ``arrays.py``) that every downstream consumer —
toposort, weights, wait counters, ``conflict_rounds``, the plan lowering —
operates on.  ``sched.tasks[i]`` / ``sched.resources[r]`` remain available
as lightweight views over that storage, so the paper's appendix-A API is
unchanged.
"""

from __future__ import annotations

import hashlib
import random
import threading
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from repro.obs import trace as _trace

from .arrays import CompiledGraph
from .locks import BaseLockManager, make_lock_manager
from .queue import TaskQueue

TASK_NONE = -1
RES_NONE = -1
OWNER_NONE = -1

FLAG_NONE = 0
FLAG_VIRTUAL = 1  # grouping-only task: scheduled but not passed to fun

_EMPTY = np.empty(0, dtype=np.int64)


class _EdgeList:
    """Append-only (a, b) id-pair store mixing per-call appends (Python tail
    lists) with bulk numpy chunks, folded lazily into one array pair.
    Insertion order is preserved across both paths."""

    __slots__ = ("chunks", "ta", "tb")

    def __init__(self):
        self.chunks: List = []
        self.ta: List[int] = []
        self.tb: List[int] = []

    def append(self, a: int, b: int) -> None:
        self.ta.append(a)
        self.tb.append(b)

    def _fold_tail(self) -> None:
        if self.ta:
            self.chunks.append((np.asarray(self.ta, dtype=np.int64),
                                np.asarray(self.tb, dtype=np.int64)))
            self.ta = []
            self.tb = []

    def extend_arrays(self, a: np.ndarray, b: np.ndarray) -> None:
        self._fold_tail()
        self.chunks.append((a, b))

    def __len__(self) -> int:
        return sum(c[0].size for c in self.chunks) + len(self.ta)

    def arrays(self):
        """(a_array, b_array) in insertion order; collapses storage to one
        chunk so repeated calls are O(1)."""
        self._fold_tail()
        if not self.chunks:
            return _EMPTY, _EMPTY
        if len(self.chunks) > 1:
            self.chunks = [(np.concatenate([c[0] for c in self.chunks]),
                            np.concatenate([c[1] for c in self.chunks]))]
        return self.chunks[0]

    def pairs(self):
        a, b = self.arrays()
        return zip(a.tolist(), b.tolist())


class Task:
    """View of one task over the scheduler's struct-of-arrays storage.

    Reads are always consistent with the underlying arrays; ``weight`` and
    ``cost`` writes go straight through (the priority-ablation benchmarks
    overwrite weights after ``prepare()``).  The adjacency properties
    (``unlocks``/``locks``/``uses``) are read-only snapshots — mutate the
    graph through ``addunlock``/``addlock``/``adduse``.
    """

    __slots__ = ("_s", "tid")

    def __init__(self, sched: "QSched", tid: int):
        self._s = sched
        self.tid = tid

    @property
    def type(self) -> int:
        return self._s._ttype[self.tid]

    @property
    def data(self) -> Any:
        return self._s._tdata[self.tid]

    @property
    def cost(self) -> float:
        return self._s._tcost[self.tid]

    @cost.setter
    def cost(self, v: float) -> None:
        self._s._tcost[self.tid] = float(v)
        self._s._prepared = False
        self._s._shash = None

    @property
    def flags(self) -> int:
        return self._s._tflags[self.tid]

    @property
    def weight(self) -> float:
        w = self._s._weight
        return float(w[self.tid]) if w is not None else 0.0

    @weight.setter
    def weight(self, v: float) -> None:
        self._s._ensure_weight()[self.tid] = v
        self._s._shash = None

    @property
    def wait(self) -> int:
        w = self._s._wait
        return int(w[self.tid]) if w is not None else 0

    @property
    def unlocks(self) -> List[int]:
        return self._s._adj()[0][self.tid]

    @property
    def locks(self) -> List[int]:
        return self._s._adj()[1][self.tid]

    @property
    def uses(self) -> List[int]:
        return self._s._adj()[2][self.tid]

    def __repr__(self) -> str:
        return (f"Task(tid={self.tid}, type={self.type}, data={self.data!r}, "
                f"cost={self.cost}, weight={self.weight})")


class Resource:
    """View of one resource (id, parent, owner) over the array storage."""

    __slots__ = ("_s", "rid")

    def __init__(self, sched: "QSched", rid: int):
        self._s = sched
        self.rid = rid

    @property
    def parent(self) -> int:
        return self._s._res_parent[self.rid]

    @property
    def owner(self) -> int:
        return self._s._res_owner[self.rid]

    @owner.setter
    def owner(self, v: int) -> None:
        self._s._res_owner[self.rid] = v
        self._s._shash = None

    def __repr__(self) -> str:
        return (f"Resource(rid={self.rid}, parent={self.parent}, "
                f"owner={self.owner})")


class _Seq:
    """Indexable/iterable view sequence (``sched.tasks``, ``sched.resources``)."""

    __slots__ = ("_s", "_cls", "_len")

    def __init__(self, sched: "QSched", cls, length: Callable[[], int]):
        self._s = sched
        self._cls = cls
        self._len = length

    def __len__(self) -> int:
        return self._len()

    def __getitem__(self, i: int):
        n = self._len()
        if i < 0:
            i += n
        if not (0 <= i < n):
            raise IndexError(i)
        return self._cls(self._s, i)

    def __iter__(self):
        for i in range(self._len()):
            yield self._cls(self._s, i)


class QSched:
    """Task scheduler with dependencies and conflicts.

    ``reown=True`` re-assigns resource ownership to the stealing queue
    (paper §3.4); the QR benchmark enables it, Barnes-Hut disables it.
    """

    def __init__(self, nr_queues: int = 1, reown: bool = True,
                 seed: int = 0):
        # struct-of-arrays task storage (parallel lists during build)
        self._ttype: List[int] = []
        self._tdata: List[Any] = []
        self._tcost: List[float] = []
        self._tflags: List[int] = []
        # COO edges / locks / uses (hybrid list/array chunk storage)
        self._deps = _EdgeList()
        self._locks = _EdgeList()
        self._uses = _EdgeList()
        # resources
        self._res_parent: List[int] = []
        self._res_owner: List[int] = []
        self.graph: Optional[CompiledGraph] = None
        self._adj_cache = None     # (version, unlocks, locks, uses)
        self._weight: Optional[np.ndarray] = None
        self._wait: Optional[List[int]] = None
        self._shash = None         # (version, hash) memo for structural_hash

        # cached view sequences (lengths resolve lazily through callables)
        self._tasks_seq = _Seq(self, Task, lambda: len(self._ttype))
        self._res_seq = _Seq(self, Resource, lambda: len(self._res_parent))

        self.nr_queues = nr_queues
        self.reown = reown
        self._rng = random.Random(seed)
        self._prepared = False
        # populated by prepare()/start():
        self.lockmgr: Optional[BaseLockManager] = None
        self.queues: List[TaskQueue] = []
        self.waiting = 0
        self._waiting_mutex = threading.Lock()
        self.topo_order: List[int] = []
        # bookkeeping for benchmarks / the paper's overhead accounting
        # (Fig 13): lock_failures counts failed all-or-nothing lockres
        # attempts in gettask (previously silently retried)
        self.steals = 0
        self.gettask_calls = 0
        self.lock_failures = 0

    # -- graph construction (paper appendix A API) --------------------------
    def addtask(self, type: int = 0, data: Any = None, cost: float = 1.0,
                flags: int = FLAG_NONE) -> int:
        tid = len(self._ttype)
        self._ttype.append(type)
        self._tdata.append(data)
        self._tcost.append(float(cost))
        self._tflags.append(flags)
        return tid

    def addres(self, owner: int = OWNER_NONE, parent: int = RES_NONE) -> int:
        rid = len(self._res_parent)
        if parent != RES_NONE and not (0 <= parent < rid):
            raise ValueError(f"invalid parent resource {parent}")
        self._res_parent.append(parent)
        self._res_owner.append(owner)
        return rid

    def addlock(self, t: int, r: int) -> None:
        if not 0 <= t < len(self._ttype):
            raise ValueError(
                f"addlock: task id {t} out of range [0, {len(self._ttype)})")
        if not 0 <= r < len(self._res_parent):
            raise ValueError(
                f"addlock: resource id {r} out of range "
                f"[0, {len(self._res_parent)})")
        self._locks.append(t, r)

    def adduse(self, t: int, r: int) -> None:
        if not 0 <= t < len(self._ttype):
            raise ValueError(
                f"adduse: task id {t} out of range [0, {len(self._ttype)})")
        if not 0 <= r < len(self._res_parent):
            raise ValueError(
                f"adduse: resource id {r} out of range "
                f"[0, {len(self._res_parent)})")
        self._uses.append(t, r)

    def addunlock(self, ta: int, tb: int) -> None:
        """tb depends on ta (ta unlocks tb)."""
        if ta == tb:
            raise ValueError("task cannot depend on itself")
        n = len(self._ttype)
        if not 0 <= ta < n:
            raise ValueError(
                f"addunlock: task id {ta} out of range [0, {n})")
        if not 0 <= tb < n:
            raise ValueError(
                f"addunlock: task id {tb} out of range [0, {n})")
        self._deps.append(ta, tb)

    # -- bulk construction (array-native fast path) --------------------------
    def addtasks(self, types, costs, datas: Sequence[Any],
                 flags: Optional[Sequence[int]] = None) -> np.ndarray:
        """Vectorized ``addtask``: append whole arrays (or plain lists) of
        tasks at once.  Returns the new task ids as an array."""
        tlist = types.tolist() if isinstance(types, np.ndarray) else types
        clist = costs.tolist() if isinstance(costs, np.ndarray) else costs
        k = len(tlist)
        if not (len(clist) == k and len(datas) == k
                and (flags is None or len(flags) == k)):
            raise ValueError(
                f"addtasks: mismatched lengths types={k} "
                f"costs={len(clist)} datas={len(datas)}"
                + (f" flags={len(flags)}" if flags is not None else ""))
        n0 = len(self._ttype)
        self._ttype.extend(tlist)
        self._tcost.extend(clist)
        self._tdata.extend(datas)
        self._tflags.extend([FLAG_NONE] * k if flags is None else list(flags))
        return np.arange(n0, n0 + k, dtype=np.int64)

    def _check_ids(self, arr: np.ndarray, limit: int, what: str,
                   who: str) -> None:
        if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= limit):
            bad = arr[(arr < 0) | (arr >= limit)]
            raise ValueError(
                f"{who}: {what} id(s) {bad[:8].tolist()} out of range "
                f"[0, {limit})")

    def addunlocks(self, src, dst) -> None:
        """Vectorized ``addunlock`` over parallel id arrays."""
        src = np.asarray(src, dtype=np.int64).ravel()
        dst = np.asarray(dst, dtype=np.int64).ravel()
        if src.shape != dst.shape:
            raise ValueError("addunlocks: src/dst length mismatch")
        n = len(self._ttype)
        self._check_ids(src, n, "task", "addunlocks")
        self._check_ids(dst, n, "task", "addunlocks")
        if src.size and bool((src == dst).any()):
            raise ValueError("task cannot depend on itself")
        self._deps.extend_arrays(src, dst)

    def addlocks(self, ts, rs) -> None:
        """Vectorized ``addlock`` over parallel id arrays."""
        ts = np.asarray(ts, dtype=np.int64).ravel()
        rs = np.asarray(rs, dtype=np.int64).ravel()
        if ts.shape != rs.shape:
            raise ValueError("addlocks: task/resource length mismatch")
        self._check_ids(ts, len(self._ttype), "task", "addlocks")
        self._check_ids(rs, len(self._res_parent), "resource", "addlocks")
        self._locks.extend_arrays(ts, rs)

    def adduses(self, ts, rs) -> None:
        """Vectorized ``adduse`` over parallel id arrays."""
        ts = np.asarray(ts, dtype=np.int64).ravel()
        rs = np.asarray(rs, dtype=np.int64).ravel()
        if ts.shape != rs.shape:
            raise ValueError("adduses: task/resource length mismatch")
        self._check_ids(ts, len(self._ttype), "task", "adduses")
        self._check_ids(rs, len(self._res_parent), "resource", "adduses")
        self._uses.extend_arrays(ts, rs)

    # -- derived structure ----------------------------------------------------
    @property
    def tasks(self) -> _Seq:
        return self._tasks_seq

    @property
    def resources(self) -> _Seq:
        return self._res_seq

    @property
    def nr_tasks(self) -> int:
        return len(self._ttype)

    @property
    def nr_resources(self) -> int:
        return len(self._res_parent)

    @property
    def nr_deps(self) -> int:
        return len(self._deps)

    @property
    def nr_locks(self) -> int:
        return len(self._locks)

    @property
    def nr_uses(self) -> int:
        return len(self._uses)

    def set_costs(self, costs: Sequence[float]) -> None:
        """Feed back measured task costs (the paper: 'the actual cost of the
        same task last time it was executed')."""
        if len(costs) != len(self._tcost):
            raise ValueError(
                f"set_costs: got {len(costs)} costs for "
                f"{len(self._tcost)} tasks")
        self._tcost = [float(c) for c in costs]
        self._prepared = False
        self._shash = None

    # -- compiled views -------------------------------------------------------
    def _sig(self):
        """Structural version: derived from the append-only list lengths, so
        graph construction pays zero bookkeeping per call."""
        return (len(self._ttype), len(self._deps), len(self._locks),
                len(self._uses), len(self._res_parent))

    def _is_prepared(self) -> bool:
        return (self._prepared and self.graph is not None
                and self.graph.version == self._sig())

    def _compiled(self) -> CompiledGraph:
        """Structure compile, cached per structural version (costs and
        weights do not invalidate it)."""
        sig = self._sig()
        if self.graph is None or self.graph.version != sig:
            with _trace.span("core.compile", tasks=len(self._ttype),
                             deps=len(self._deps)):
                dep_src, dep_dst = self._deps.arrays()
                lock_t, lock_r = self._locks.arrays()
                use_t, use_r = self._uses.arrays()
                self.graph = CompiledGraph(
                    sig, len(self._ttype), len(self._res_parent),
                    dep_src, dep_dst, lock_t, lock_r, use_t, use_r)
            self._adj_cache = None
        return self.graph

    def _adj(self):
        """(unlocks, locks, uses) lists-of-lists for the current version —
        from the compiled CSR when available, else built from the COO lists
        (pre-``prepare()`` reads; locks unsorted there, as before)."""
        g = self.graph
        if g is not None and g.version == self._sig():
            return g.unlocks_list, g.locks_list, g.uses_list
        if self._adj_cache is None or self._adj_cache[0] != self._sig():
            n = len(self._ttype)
            unlocks: List[List[int]] = [[] for _ in range(n)]
            locks: List[List[int]] = [[] for _ in range(n)]
            uses: List[List[int]] = [[] for _ in range(n)]
            for a, b in self._deps.pairs():
                unlocks[a].append(b)
            for t, r in self._locks.pairs():
                locks[t].append(r)
            for t, r in self._uses.pairs():
                uses[t].append(r)
            self._adj_cache = (self._sig(), unlocks, locks, uses)
        return self._adj_cache[1], self._adj_cache[2], self._adj_cache[3]

    def _ensure_weight(self) -> np.ndarray:
        if self._weight is None or self._weight.shape[0] != len(self._ttype):
            self._weight = np.zeros(len(self._ttype), dtype=np.float64)
        return self._weight

    def structural_hash(self) -> str:
        """Hash of the compiled structure + task types/flags/costs +
        weights + resource forest/ownership — the ExecutionPlan cache key
        (two graphs with equal hashes lower to identical plans).  Memoized
        per structural version; cost/weight/ownership mutations invalidate
        the memo."""
        g = self._compiled()
        if (not self._is_prepared() or self._weight is None
                or self._weight.shape[0] != g.n):
            self.prepare()
        if self._shash is not None and self._shash[0] == g.version:
            return self._shash[1]
        h = hashlib.blake2b(digest_size=16)
        h.update(f"{g.n},{g.nres}".encode())
        for arr in (g.unlocks_indptr, g.unlocks_indices,
                    g.locks_indptr, g.locks_indices,
                    g.uses_indptr, g.uses_indices):
            h.update(arr.tobytes())
        h.update(np.asarray(self._ttype, dtype=np.int64).tobytes())
        h.update(np.asarray(self._tflags, dtype=np.int64).tobytes())
        h.update(np.asarray(self._tcost, dtype=np.float64).tobytes())
        h.update(self._weight.tobytes())
        h.update(np.asarray(self._res_parent, dtype=np.int64).tobytes())
        h.update(np.asarray(self._res_owner, dtype=np.int64).tobytes())
        self._shash = (g.version, h.hexdigest())
        return self._shash[1]

    def prepare(self) -> None:
        """Compile the graph structure to CSR (once per version), then run
        the vectorized Kahn toposort + critical-path sweep; lock lists come
        out sorted by resource id (deadlock avoidance, paper §3.3)."""
        with _trace.span("core.prepare", tasks=self.nr_tasks,
                         deps=self.nr_deps):
            g = self._compiled()
            cost = np.asarray(self._tcost, dtype=np.float64)
            self._weight = g.weights(cost)
            self._wait = g.wait0.tolist()
            self.topo_order = g.order.tolist()
            self._prepared = True
            self._shash = None

    # -- execution protocol (paper §3.4) ---------------------------------------
    def start(self, threaded: bool = False) -> None:
        """qsched_start: build lock manager + queues, enqueue ready tasks."""
        if not self._is_prepared():
            self.prepare()
        g = self._compiled()
        self.lockmgr = make_lock_manager(self._res_parent, threaded)
        wtab = self._ensure_weight().tolist()
        self.queues = [TaskQueue(wtab, threaded) for _ in range(self.nr_queues)]
        self.waiting = self.nr_tasks
        self.steals = 0
        self.gettask_calls = 0
        self.lock_failures = 0
        self._wait = g.wait0.tolist()
        for tid in np.flatnonzero(g.wait0 == 0).tolist():
            self.enqueue(tid)

    def enqueue(self, tid: int) -> None:
        """qsched_enqueue: score queues by how many of the task's resources
        they own; send the task to the highest-scoring queue."""
        g = self.graph
        owner = self._res_owner
        score = [0] * self.nr_queues
        best = 0
        for r in g.locks_list[tid]:
            o = owner[r]
            if o != OWNER_NONE:
                score[o] += 1
                if score[o] > score[best]:
                    best = o
        for r in g.uses_list[tid]:
            o = owner[r]
            if o != OWNER_NONE:
                score[o] += 1
                if score[o] > score[best]:
                    best = o
        self.queues[best].put(tid)

    def _try_lock_task(self, tid: int) -> bool:
        ok = self.lockmgr.lock_all(self.graph.locks_list[tid])
        if not ok:
            # the paper's overhead accounting: a failed all-or-nothing
            # lockres attempt that gettask silently retries.  Exact under
            # threading (mutex-guarded); the failure path is off the
            # contention-free fast path so the cost is paid only when a
            # conflict actually occurred.
            with self._waiting_mutex:
                self.lock_failures += 1
        return ok

    def gettask(self, qid: int, block: bool = False) -> Optional[int]:
        """qsched_gettask: preferred queue first, then work-steal from the
        other queues in random order.  Non-blocking by default (the
        simulator retries on events); ``block`` spins like the paper's
        OpenMP loop and is used by the threaded executor."""
        while True:
            self.gettask_calls += 1
            if self.waiting <= 0:
                return None
            tid = self.queues[qid].get(self._try_lock_task)
            if tid is None and self.nr_queues > 1:
                others = [k for k in range(self.nr_queues) if k != qid]
                self._rng.shuffle(others)
                for k in others:
                    tid = self.queues[k].get(self._try_lock_task)
                    if tid is not None:
                        self.steals += 1
                        break
            if tid is not None:
                if self.reown:
                    g = self.graph
                    owner = self._res_owner
                    for r in g.locks_list[tid]:
                        owner[r] = qid
                    for r in g.uses_list[tid]:
                        owner[r] = qid
                    self._shash = None   # ownership feeds the plan hash
                return tid
            if not block:
                return None

    def done(self, tid: int) -> List[int]:
        """qsched_done: release resources, unlock dependents, enqueue any
        whose wait hits zero.  Returns the newly-released task ids."""
        g = self.graph
        self.lockmgr.unlock_all(g.locks_list[tid])
        wait = self._wait
        released: List[int] = []
        for j in g.unlocks_list[tid]:
            with self._waiting_mutex:
                wait[j] -= 1
                ready = wait[j] == 0
            if ready:
                self.enqueue(j)
                released.append(j)
        with self._waiting_mutex:
            self.waiting -= 1
        return released

    # -- convenience -----------------------------------------------------------
    def run_threaded(self, nr_threads: int, fun: Callable[[int, Any], None]) -> None:
        """qsched_run with a pthread-style pool (paper §3.4).  ``fun`` is
        called as fun(type, data) for every non-virtual task."""
        from .executors import ThreadedExecutor

        ThreadedExecutor(self, nr_threads).run(fun)

    def validate_schedule(self, timeline) -> None:
        """Assert a (task, worker, t0, t1) timeline respects dependencies and
        conflicts — used by tests and the property suite."""
        unlocks, locks, _ = self._adj()
        start = {e.tid: e.t0 for e in timeline}
        end = {e.tid: e.t1 for e in timeline}
        assert len(start) == self.nr_tasks, "not all tasks executed"
        for tid in range(self.nr_tasks):
            for j in unlocks[tid]:
                assert start[j] >= end[tid] - 1e-9, (
                    f"dependency violated: {j} started {start[j]} before "
                    f"{tid} finished {end[tid]}"
                )
        # conflicts: tasks locking overlapping resource subtrees must not
        # overlap in time.  Expand each task's locks to cover descendants via
        # ancestor chains: two tasks conflict iff one's locked resource is an
        # ancestor-or-self of the other's.
        anc = {}
        parents = self._res_parent

        def ancestors(r):
            if r not in anc:
                chain = set()
                u = r
                while u != RES_NONE:
                    chain.add(u)
                    u = parents[u]
                anc[r] = chain
            return anc[r]

        by_res = {}
        for e in timeline:
            for r in locks[e.tid]:
                for a in ancestors(r):
                    by_res.setdefault(a, []).append(e)
        for r, evs in by_res.items():
            evs.sort(key=lambda e: e.t0)
            for a, b in zip(evs, evs[1:]):
                # siblings both holding ancestor r do not conflict; only
                # pairs where one locks r itself do.
                if r in locks[a.tid] or r in locks[b.tid]:
                    assert b.t0 >= a.t1 - 1e-9, (
                        f"conflict violated on resource {r}: tasks "
                        f"{a.tid}@[{a.t0},{a.t1}) and {b.tid}@[{b.t0},{b.t1})"
                    )
