"""The qsched object: tasks, resources, dependencies, conflicts (paper §3.1–3.4).

The full task graph is constructed explicitly *before* execution
(``addtask`` / ``addres`` / ``addlock`` / ``adduse`` / ``addunlock``), then
``prepare()`` computes wait counters and critical-path weights.  Execution
engines (simulator, threaded executor, static scheduler) drive the same
``start`` / ``gettask`` / ``done`` protocol.
"""

from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

from .locks import BaseLockManager, make_lock_manager
from .queue import TaskQueue
from .weights import critical_path_weights

TASK_NONE = -1
RES_NONE = -1
OWNER_NONE = -1

FLAG_NONE = 0
FLAG_VIRTUAL = 1  # grouping-only task: scheduled but not passed to fun


@dataclass
class Task:
    tid: int
    type: int
    data: Any
    cost: float
    flags: int = FLAG_NONE
    unlocks: List[int] = field(default_factory=list)  # tasks this task unlocks
    locks: List[int] = field(default_factory=list)    # resources to lock (conflicts)
    uses: List[int] = field(default_factory=list)     # resources used (affinity only)
    wait: int = 0                                     # unresolved dependencies
    weight: float = 0.0                               # critical-path weight


@dataclass
class Resource:
    rid: int
    parent: int = RES_NONE
    owner: int = OWNER_NONE  # queue that last used this resource


class QSched:
    """Task scheduler with dependencies and conflicts.

    ``reown=True`` re-assigns resource ownership to the stealing queue
    (paper §3.4); the QR benchmark enables it, Barnes-Hut disables it.
    """

    def __init__(self, nr_queues: int = 1, reown: bool = True,
                 seed: int = 0):
        self.tasks: List[Task] = []
        self.resources: List[Resource] = []
        self.nr_queues = nr_queues
        self.reown = reown
        self._rng = random.Random(seed)
        self._prepared = False
        # populated by prepare()/start():
        self.lockmgr: Optional[BaseLockManager] = None
        self.queues: List[TaskQueue] = []
        self.waiting = 0
        self._waiting_mutex = threading.Lock()
        self.topo_order: List[int] = []
        # bookkeeping for benchmarks
        self.steals = 0
        self.gettask_calls = 0

    # -- graph construction (paper appendix A API) --------------------------
    def addtask(self, type: int = 0, data: Any = None, cost: float = 1.0,
                flags: int = FLAG_NONE) -> int:
        tid = len(self.tasks)
        self.tasks.append(Task(tid, type, data, float(cost), flags))
        self._prepared = False
        return tid

    def addres(self, owner: int = OWNER_NONE, parent: int = RES_NONE) -> int:
        rid = len(self.resources)
        if parent != RES_NONE and not (0 <= parent < rid):
            raise ValueError(f"invalid parent resource {parent}")
        self.resources.append(Resource(rid, parent, owner))
        return rid

    def addlock(self, t: int, r: int) -> None:
        self.tasks[t].locks.append(r)
        self._prepared = False

    def adduse(self, t: int, r: int) -> None:
        self.tasks[t].uses.append(r)

    def addunlock(self, ta: int, tb: int) -> None:
        """tb depends on ta (ta unlocks tb)."""
        if ta == tb:
            raise ValueError("task cannot depend on itself")
        self.tasks[ta].unlocks.append(tb)
        self._prepared = False

    # -- derived structure ----------------------------------------------------
    @property
    def nr_tasks(self) -> int:
        return len(self.tasks)

    @property
    def nr_deps(self) -> int:
        return sum(len(t.unlocks) for t in self.tasks)

    @property
    def nr_locks(self) -> int:
        return sum(len(t.locks) for t in self.tasks)

    @property
    def nr_uses(self) -> int:
        return sum(len(t.uses) for t in self.tasks)

    def set_costs(self, costs: Sequence[float]) -> None:
        """Feed back measured task costs (the paper: 'the actual cost of the
        same task last time it was executed')."""
        for t, c in zip(self.tasks, costs):
            t.cost = float(c)
        self._prepared = False

    def prepare(self) -> None:
        """Compute wait counters + critical-path weights; sort each task's
        locks by resource id (deadlock avoidance, paper §3.3)."""
        n = self.nr_tasks
        unlocks = [t.unlocks for t in self.tasks]
        costs = [t.cost for t in self.tasks]
        weights, order = critical_path_weights(n, unlocks, costs)
        for t, w in zip(self.tasks, weights):
            t.weight = w
            t.wait = 0
            t.locks.sort()
        for t in self.tasks:
            for j in t.unlocks:
                self.tasks[j].wait += 1
        self.topo_order = order
        self._prepared = True

    # -- execution protocol (paper §3.4) ---------------------------------------
    def start(self, threaded: bool = False) -> None:
        """qsched_start: build lock manager + queues, enqueue ready tasks."""
        if not self._prepared:
            self.prepare()
        parents = [r.parent for r in self.resources]
        self.lockmgr = make_lock_manager(parents, threaded)
        wtab = [t.weight for t in self.tasks]
        self.queues = [TaskQueue(wtab, threaded) for _ in range(self.nr_queues)]
        self.waiting = self.nr_tasks
        self.steals = 0
        self.gettask_calls = 0
        # wait counters were set by prepare(); recompute in case of rerun
        for t in self.tasks:
            t.wait = 0
        for t in self.tasks:
            for j in t.unlocks:
                self.tasks[j].wait += 1
        for t in self.tasks:
            if t.wait == 0:
                self.enqueue(t.tid)

    def enqueue(self, tid: int) -> None:
        """qsched_enqueue: score queues by how many of the task's resources
        they own; send the task to the highest-scoring queue."""
        t = self.tasks[tid]
        score = [0] * self.nr_queues
        best = 0
        for r in t.locks:
            o = self.resources[r].owner
            if o != OWNER_NONE:
                score[o] += 1
                if score[o] > score[best]:
                    best = o
        for r in t.uses:
            o = self.resources[r].owner
            if o != OWNER_NONE:
                score[o] += 1
                if score[o] > score[best]:
                    best = o
        self.queues[best].put(tid)

    def _try_lock_task(self, tid: int) -> bool:
        return self.lockmgr.lock_all(self.tasks[tid].locks)

    def gettask(self, qid: int, block: bool = False) -> Optional[int]:
        """qsched_gettask: preferred queue first, then work-steal from the
        other queues in random order.  Non-blocking by default (the
        simulator retries on events); ``block`` spins like the paper's
        OpenMP loop and is used by the threaded executor."""
        while True:
            self.gettask_calls += 1
            if self.waiting <= 0:
                return None
            tid = self.queues[qid].get(self._try_lock_task)
            if tid is None and self.nr_queues > 1:
                others = [k for k in range(self.nr_queues) if k != qid]
                self._rng.shuffle(others)
                for k in others:
                    tid = self.queues[k].get(self._try_lock_task)
                    if tid is not None:
                        self.steals += 1
                        break
            if tid is not None:
                if self.reown:
                    t = self.tasks[tid]
                    for r in t.locks:
                        self.resources[r].owner = qid
                    for r in t.uses:
                        self.resources[r].owner = qid
                return tid
            if not block:
                return None

    def done(self, tid: int) -> List[int]:
        """qsched_done: release resources, unlock dependents, enqueue any
        whose wait hits zero.  Returns the newly-released task ids."""
        t = self.tasks[tid]
        self.lockmgr.unlock_all(t.locks)
        released: List[int] = []
        for j in t.unlocks:
            dep = self.tasks[j]
            with self._waiting_mutex:
                dep.wait -= 1
                ready = dep.wait == 0
            if ready:
                self.enqueue(j)
                released.append(j)
        with self._waiting_mutex:
            self.waiting -= 1
        return released

    # -- convenience -----------------------------------------------------------
    def run_threaded(self, nr_threads: int, fun: Callable[[int, Any], None]) -> None:
        """qsched_run with a pthread-style pool (paper §3.4).  ``fun`` is
        called as fun(type, data) for every non-virtual task."""
        from .executors import ThreadedExecutor

        ThreadedExecutor(self, nr_threads).run(fun)

    def validate_schedule(self, timeline) -> None:
        """Assert a (task, worker, t0, t1) timeline respects dependencies and
        conflicts — used by tests and the property suite."""
        start = {e.tid: e.t0 for e in timeline}
        end = {e.tid: e.t1 for e in timeline}
        assert len(start) == self.nr_tasks, "not all tasks executed"
        for t in self.tasks:
            for j in t.unlocks:
                assert start[j] >= end[t.tid] - 1e-9, (
                    f"dependency violated: {j} started {start[j]} before "
                    f"{t.tid} finished {end[t.tid]}"
                )
        # conflicts: tasks locking overlapping resource subtrees must not
        # overlap in time.  Expand each task's locks to cover descendants via
        # ancestor chains: two tasks conflict iff one's locked resource is an
        # ancestor-or-self of the other's.
        anc = {}
        parents = [r.parent for r in self.resources]

        def ancestors(r):
            if r not in anc:
                chain = set()
                u = r
                while u != RES_NONE:
                    chain.add(u)
                    u = parents[u]
                anc[r] = chain
            return anc[r]

        by_res = {}
        for e in timeline:
            for r in self.tasks[e.tid].locks:
                for a in ancestors(r):
                    by_res.setdefault(a, []).append(e)
        for r, evs in by_res.items():
            evs.sort(key=lambda e: e.t0)
            for a, b in zip(evs, evs[1:]):
                # siblings both holding ancestor r do not conflict; only
                # pairs where one locks r itself do.
                if r in self.tasks[a.tid].locks or r in self.tasks[b.tid].locks:
                    assert b.t0 >= a.t1 - 1e-9, (
                        f"conflict violated on resource {r}: tasks "
                        f"{a.tid}@[{a.t0},{a.t1}) and {b.tid}@[{b.t0},{b.t1})"
                    )
