"""Array-native compiled task-graph representation (numpy struct-of-arrays).

``QSched`` accumulates the graph as flat COO edge lists during construction
(cheap ``list.append`` per call, no per-task objects); ``prepare()`` compiles
them into this CSR form once per structural version.  Everything downstream
— the vectorized Kahn toposort, the critical-path sweep, wait-counter
initialisation, and the ``ExecutionPlan`` lowering — runs over these arrays
instead of walking per-task Python objects.

The toposort processes the DAG level-by-level: each iteration gathers the
out-edges of the whole frontier with one CSR multi-slice (``csr_gather``),
decrements in-degrees with ``bincount``, and emits the next frontier with
``flatnonzero``.  The level structure is kept (``level_ptr``) so the
critical-path sweep can run one vectorized segment-max per level in reverse.
The float operations per task are identical to the reference implementation
in ``weights.py`` (``cost[i] + max(weight[succ])``), so the weights agree
bitwise — property-tested in ``tests/test_plan.py``.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def coo_to_csr(n: int, src: Sequence[int], dst: Sequence[int],
               sort_cols: bool = False) -> Tuple[np.ndarray, np.ndarray]:
    """Compile COO edge lists into CSR (indptr, indices).

    Insertion order is preserved within a row (stable sort) unless
    ``sort_cols`` is set, which additionally sorts each row's columns
    ascending — used for lock lists (paper §3.3 deadlock-avoidance order).
    """
    s = np.asarray(src, dtype=np.int64)
    d = np.asarray(dst, dtype=np.int64)
    if sort_cols and s.size:
        perm = np.lexsort((d, s))
    elif s.size:
        perm = np.argsort(s, kind="stable")
    else:
        perm = np.empty(0, dtype=np.int64)
    indices = d[perm] if perm.size else d
    counts = np.bincount(s, minlength=n) if s.size else np.zeros(n, np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, indices


def csr_gather(indptr: np.ndarray, indices: np.ndarray,
               nodes: np.ndarray) -> np.ndarray:
    """Concatenate ``indices[indptr[i]:indptr[i+1]]`` for every i in
    ``nodes``, fully vectorized.  Output stays grouped by node (segments in
    ``nodes`` order), which ``np.maximum.reduceat`` relies on."""
    deg = indptr[nodes + 1] - indptr[nodes]
    total = int(deg.sum())
    if total == 0:
        return indices[:0]
    cum = np.cumsum(deg)
    pos = (np.repeat(indptr[nodes] - (cum - deg), deg)
           + np.arange(total, dtype=np.int64))
    return indices[pos]


def toposort_levels(n: int, indptr: np.ndarray, indices: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, List[np.ndarray]]:
    """Vectorized Kahn's algorithm.  Returns (order, level_ptr, level_succ)
    where ``order[level_ptr[k]:level_ptr[k+1]]`` is the k-th dependency
    level and ``level_succ[k]`` is the gathered successor array of that
    level (kept for the critical-path sweep, which re-walks the same
    frontiers).  Raises ``ValueError`` on a cycle (same contract as
    ``weights.toposort``)."""
    indeg = (np.bincount(indices, minlength=n).astype(np.int64)
             if indices.size else np.zeros(n, np.int64))
    frontier = np.flatnonzero(indeg == 0)
    order = np.empty(n, dtype=np.int64)
    level_ptr = [0]
    level_succ: List[np.ndarray] = []
    filled = 0
    while frontier.size:
        order[filled:filled + frontier.size] = frontier
        filled += frontier.size
        level_ptr.append(filled)
        succ = csr_gather(indptr, indices, frontier)
        level_succ.append(succ)
        if succ.size == 0:
            break
        dec = np.bincount(succ, minlength=n)
        indeg -= dec
        frontier = np.flatnonzero((indeg == 0) & (dec > 0))
    if filled != n:
        cyclic = np.flatnonzero(indeg > 0)
        raise ValueError(
            f"dependency cycle detected involving {cyclic.size} tasks "
            f"(e.g. ids {cyclic[:8].tolist()})"
        )
    return order, np.asarray(level_ptr, dtype=np.int64), level_succ


def critical_path_sweep(n: int, indptr: np.ndarray, indices: np.ndarray,
                        cost: np.ndarray, order: np.ndarray,
                        level_ptr: np.ndarray,
                        level_succ: List[np.ndarray]) -> np.ndarray:
    """Paper §3.1 recurrence ``w_i = cost_i + max_j∈unlocks(i) w_j`` as one
    vectorized segment-max per level, deepest level first, reusing the
    successor gathers recorded by ``toposort_levels``."""
    weight = np.zeros(n, dtype=np.float64)
    for lv in range(len(level_ptr) - 2, -1, -1):
        nodes = order[level_ptr[lv]:level_ptr[lv + 1]]
        succ = (level_succ[lv] if lv < len(level_succ)
                else indices[:0])
        best = np.zeros(nodes.size, dtype=np.float64)
        if succ.size:
            deg = indptr[nodes + 1] - indptr[nodes]
            nz = deg > 0
            # segment starts within the gathered array: zero-degree nodes
            # contribute no elements, so the starts of the nonzero-degree
            # nodes partition it exactly.
            cum = np.cumsum(deg)
            starts = (cum - deg)[nz]
            best[nz] = np.maximum.reduceat(weight[succ], starts)
        weight[nodes] = cost[nodes] + best
    return weight


def _split_rows(indptr: np.ndarray, indices: np.ndarray) -> List[List[int]]:
    flat = indices.tolist()
    ip = indptr.tolist()
    return [flat[a:b] for a, b in zip(ip, ip[1:])]


class CompiledGraph:
    """Immutable CSR snapshot of a QSched graph's *structure* (edges, locks,
    uses, in-degrees, topo levels).  Weights live on the scheduler — they
    change with costs without invalidating the structure.  The lists-of-lists
    mirrors (``unlocks_list`` …) are built lazily for the per-task hot loops
    (lock attempts, dependency release) that stay in Python."""

    __slots__ = ("version", "n", "nres",
                 "unlocks_indptr", "unlocks_indices",
                 "locks_indptr", "locks_indices",
                 "uses_indptr", "uses_indices",
                 "wait0", "order", "level_ptr", "level_succ",
                 "_unlocks_list", "_locks_list", "_uses_list")

    def __init__(self, version: int, n: int, nres: int,
                 dep_src: Sequence[int], dep_dst: Sequence[int],
                 lock_t: Sequence[int], lock_r: Sequence[int],
                 use_t: Sequence[int], use_r: Sequence[int]):
        self.version = version
        self.n = n
        self.nres = nres
        self.unlocks_indptr, self.unlocks_indices = coo_to_csr(
            n, dep_src, dep_dst)
        self.locks_indptr, self.locks_indices = coo_to_csr(
            n, lock_t, lock_r, sort_cols=True)
        self.uses_indptr, self.uses_indices = coo_to_csr(n, use_t, use_r)
        self.wait0 = (np.bincount(self.unlocks_indices, minlength=n)
                      .astype(np.int64)
                      if self.unlocks_indices.size else np.zeros(n, np.int64))
        self.order, self.level_ptr, self.level_succ = toposort_levels(
            n, self.unlocks_indptr, self.unlocks_indices)
        self._unlocks_list = None
        self._locks_list = None
        self._uses_list = None

    def weights(self, cost: np.ndarray) -> np.ndarray:
        return critical_path_sweep(self.n, self.unlocks_indptr,
                                   self.unlocks_indices, cost,
                                   self.order, self.level_ptr,
                                   self.level_succ)

    @property
    def unlocks_list(self) -> List[List[int]]:
        if self._unlocks_list is None:
            self._unlocks_list = _split_rows(self.unlocks_indptr,
                                             self.unlocks_indices)
        return self._unlocks_list

    @property
    def locks_list(self) -> List[List[int]]:
        if self._locks_list is None:
            self._locks_list = _split_rows(self.locks_indptr,
                                           self.locks_indices)
        return self._locks_list

    @property
    def uses_list(self) -> List[List[int]]:
        if self._uses_list is None:
            self._uses_list = _split_rows(self.uses_indptr, self.uses_indices)
        return self._uses_list
