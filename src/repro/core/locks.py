"""Hierarchical resource lock/hold protocol (paper §3.2).

A resource may be *locked* (exclusive) or *held* (one of its descendants is
locked).  Locking a resource requires (a) the resource itself not being held
or locked and (b) *holding* every ancestor up to the root.  A held resource
cannot be locked; a locked resource cannot be held.  This is what lets a
conflict between tasks be expressed at any level of a resource hierarchy
(e.g. octree cells).

Two lock managers share the protocol:

* ``SeqLockManager`` — plain integers, for the discrete-event simulator and
  the static scheduler (single control thread, no races possible).
* ``ThreadedLockManager`` — emulates the paper's ``atomic_cas`` /
  ``atomic_inc`` with a per-resource mutex guarding only the atomic ops, for
  the host-side threaded executor.  The *protocol* (including the paper's
  re-check of ``hold`` after acquiring ``lock`` to close the hold/lock race)
  is identical in both.
"""

from __future__ import annotations

import threading
from typing import List, Optional


class _ResourceState:
    __slots__ = ("lock", "hold", "mutex")

    def __init__(self, threaded: bool):
        self.lock = 0
        self.hold = 0
        self.mutex = threading.Lock() if threaded else None


class BaseLockManager:
    """Shared lock/hold protocol over a resource forest.

    ``parents[r]`` is the parent resource id of ``r`` or -1.
    """

    threaded = False

    def __init__(self, parents: List[int]):
        self.parents = parents
        self.state = [_ResourceState(self.threaded) for _ in parents]

    # -- atomic primitives (overridden for the threaded manager) ----------
    def _cas_lock(self, s: _ResourceState) -> bool:
        if s.lock == 0:
            s.lock = 1
            return True
        return False

    def _inc_hold(self, s: _ResourceState) -> None:
        s.hold += 1

    def _dec_hold(self, s: _ResourceState) -> None:
        s.hold -= 1

    # -- protocol (paper §3.2) --------------------------------------------
    def try_hold(self, r: int) -> bool:
        """resource_hold: momentarily lock ``r`` to bump its hold counter."""
        s = self.state[r]
        if not self._cas_lock(s):
            return False
        self._inc_hold(s)
        s.lock = 0
        return True

    def try_lock(self, r: int) -> bool:
        """resource_lock: exclusive-lock ``r`` and hold all its ancestors."""
        s = self.state[r]
        if s.hold != 0:
            return False
        if not self._cas_lock(s):
            return False
        if s.hold != 0:  # re-check: a try_hold may have raced us
            s.lock = 0
            return False
        # Walk up the hierarchy holding each ancestor.
        held: List[int] = []
        up: int = self.parents[r]
        ok = True
        while up != -1:
            if not self.try_hold(up):
                ok = False
                break
            held.append(up)
            up = self.parents[up]
        if ok:
            return True
        for a in held:  # undo partial holds, release the lock
            self._dec_hold(self.state[a])
        s.lock = 0
        return False

    def unlock(self, r: int) -> None:
        s = self.state[r]
        assert s.lock == 1, f"unlock of unlocked resource {r}"
        s.lock = 0
        up = self.parents[r]
        while up != -1:
            self._dec_hold(self.state[up])
            up = self.parents[up]

    def lock_all(self, resources: List[int]) -> bool:
        """Try to lock a sorted list of resources; all-or-nothing.

        Resources must be pre-sorted by id (paper §3.3: global ordering
        avoids the dining-philosophers livelock).
        """
        for i, r in enumerate(resources):
            if not self.try_lock(r):
                for j in range(i - 1, -1, -1):
                    self.unlock(resources[j])
                return False
        return True

    def unlock_all(self, resources: List[int]) -> None:
        for r in resources:
            self.unlock(r)

    # -- introspection ------------------------------------------------------
    def is_locked(self, r: int) -> bool:
        return self.state[r].lock == 1

    def hold_count(self, r: int) -> int:
        return self.state[r].hold

    def all_free(self) -> bool:
        return all(s.lock == 0 and s.hold == 0 for s in self.state)


class SeqLockManager(BaseLockManager):
    threaded = False


class ThreadedLockManager(BaseLockManager):
    """Per-resource mutexes emulate atomic_cas/atomic_inc of the paper."""

    threaded = True

    def _cas_lock(self, s: _ResourceState) -> bool:
        with s.mutex:
            if s.lock == 0:
                s.lock = 1
                return True
            return False

    def _inc_hold(self, s: _ResourceState) -> None:
        with s.mutex:
            s.hold += 1

    def _dec_hold(self, s: _ResourceState) -> None:
        with s.mutex:
            s.hold -= 1


def make_lock_manager(parents: List[int], threaded: bool) -> BaseLockManager:
    return (ThreadedLockManager if threaded else SeqLockManager)(parents)
