"""Discrete-event simulation of the QuickSched execution protocol.

This container has a single CPU core, so the paper's 64-core wall-clock
scaling (Figs 8, 11) cannot be measured directly.  The simulator drives the
*identical* scheduler code path (queues, hierarchical locks, critical-path
priorities, work stealing, re-owning) with virtual time: a worker that
obtains a task occupies it for ``cost / speed`` time units, holding its
resource locks for the duration.  The resulting makespans give the
scheduler-limited strong-scaling curves, directly comparable to the paper's
(minus hardware effects like the Opteron L2 sharing, which the paper itself
excludes from scheduler quality).

``overhead`` models the per-gettask scheduler cost (paper Fig 13 reports it
at < 1 % of total time on 64 cores).
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.obs import trace as _trace

from .graph import FLAG_VIRTUAL, QSched


@dataclass
class TimelineEvent:
    tid: int
    worker: int
    t0: float
    t1: float
    type: int = 0


@dataclass
class SimResult:
    makespan: float
    timeline: List[TimelineEvent]
    nr_workers: int
    busy: List[float]
    per_type_cost: Dict[int, float]
    overhead_time: float
    steals: int
    gettask_calls: int

    @property
    def total_cost(self) -> float:
        return sum(e.t1 - e.t0 for e in self.timeline)

    def efficiency(self, serial_time: Optional[float] = None) -> float:
        t1 = serial_time if serial_time is not None else self.total_cost
        return t1 / (self.nr_workers * self.makespan)

    def speedup(self, serial_time: Optional[float] = None) -> float:
        t1 = serial_time if serial_time is not None else self.total_cost
        return t1 / self.makespan


def simulate(sched: QSched, nr_workers: int, overhead: float = 0.0,
             speed: float = 1.0) -> SimResult:
    """Simulate ``sched`` on ``nr_workers`` workers.  ``sched.nr_queues``
    should equal ``nr_workers`` for the paper's one-queue-per-core setup
    (but any combination is allowed)."""
    with _trace.span("sim.simulate", tasks=sched.nr_tasks,
                     workers=nr_workers):
        return _simulate(sched, nr_workers, overhead, speed)


def _simulate(sched: QSched, nr_workers: int, overhead: float,
              speed: float) -> SimResult:
    sched.start(threaded=False)
    timeline: List[TimelineEvent] = []
    busy = [0.0] * nr_workers
    per_type: Dict[int, float] = {}
    overhead_time = 0.0

    # (finish_time, seq, worker, tid) — seq breaks ties deterministically
    running: List = []
    seq = 0
    now = 0.0
    idle = list(range(nr_workers))

    def try_dispatch():
        nonlocal seq, overhead_time
        # keep handing tasks to idle workers until none can get one
        progress = True
        while idle and progress:
            progress = False
            for w in list(idle):
                qid = w % sched.nr_queues
                tid = sched.gettask(qid, block=False)
                overhead_time += overhead
                if tid is not None:
                    t = sched.tasks[tid]
                    dur = t.cost / speed + overhead
                    heapq.heappush(running, (now + dur, seq, w, tid))
                    seq += 1
                    idle.remove(w)
                    timeline.append(
                        TimelineEvent(tid, w, now, now + dur, t.type))
                    busy[w] += dur
                    per_type[t.type] = per_type.get(t.type, 0.0) + dur
                    progress = True

    try_dispatch()
    while running:
        now, _, w, tid = heapq.heappop(running)
        sched.done(tid)
        idle.append(w)
        try_dispatch()

    if sched.waiting > 0:
        raise RuntimeError(
            f"simulation deadlocked with {sched.waiting} tasks unexecuted")
    return SimResult(
        makespan=now,
        timeline=timeline,
        nr_workers=nr_workers,
        busy=busy,
        per_type_cost=per_type,
        overhead_time=overhead_time,
        steals=sched.steals,
        gettask_calls=sched.gettask_calls,
    )


def timeline_to_tracer(result: SimResult, tracer=None, *,
                       process: str = "predicted", scale: float = 1.0,
                       t_origin: float = 0.0) -> int:
    """Emit a simulated timeline as trace task records — the *same* schema
    measured executions use, so a predicted timeline and a measured one
    render as two process tracks in a single Perfetto view (the paper's
    Fig 8/13 predicted-vs-measured methodology; ROADMAP simulator
    validation).

    Virtual time maps to trace seconds as ``t_origin + t * scale``: when
    the simulation replayed *measured* costs (``replay_item_times`` /
    ``replay_round_times``), ``scale=1.0`` keeps the two tracks on one
    clock and ``t_origin`` aligns the predicted start with the measured
    one.  Records land on the global tracer unless one is passed; returns
    the number of records emitted (0 on a disabled tracer)."""
    tr = _trace.get_tracer() if tracer is None else tracer
    if not tr.enabled:
        return 0
    for e in result.timeline:
        tr.task(e.tid, e.type, e.worker,
                t_origin + e.t0 * scale, t_origin + e.t1 * scale,
                process=process)
    return len(result.timeline)


def replay_round_times(sched: QSched, plan, round_times,
                       nr_workers: int = 1, overhead: float = 0.0) -> SimResult:
    """Validate the makespan model against measured engine rounds
    (ROADMAP: simulator validation, the paper's Fig 8/13 methodology).

    Each measured per-round time (``engine.measure_round_times``) is
    distributed over that round's tasks in proportion to their static
    costs, fed back through ``set_costs`` — the paper's cost-feedback
    loop — and the discrete-event simulator replays the schedule.  With
    ``nr_workers=1`` the predicted makespan is the additive round model
    (Σ round times); with more workers it is the model's prediction of
    what lane parallelism would buy.  Costs are restored afterwards so
    the scheduler (and the plan cache keyed on its hash) is unchanged."""
    if len(round_times) != plan.nr_rounds:
        raise ValueError(
            f"{len(round_times)} round times for a {plan.nr_rounds}-round "
            f"plan")
    old_costs = list(sched._tcost)
    costs = list(old_costs)
    for rnd, rt in zip(plan.rounds, round_times):
        share = sum(old_costs[t] for t in rnd.tids)
        for t in rnd.tids:
            costs[t] = (rt * old_costs[t] / share if share > 0
                        else rt / len(rnd.tids))
    try:
        sched.set_costs(costs)
        sched.prepare()
        return simulate(sched, nr_workers, overhead=overhead)
    finally:
        sched.set_costs(old_costs)
        sched.prepare()


def replay_item_times(sched: QSched, item_tids, item_times,
                      nr_workers: int = 1, overhead: float = 0.0) -> SimResult:
    """Replay *per-item* engine measurements (``engine.measure_round_times``
    with ``per_item=True``) through the discrete-event model.

    Where :func:`replay_round_times` can only distribute a round's wall
    time over its tasks by static cost share (an additive, 1-worker model),
    per-item measurements give each task its *own* measured cost — the sum
    of its descriptor items' times (``item_tids`` maps items back to
    tasks, ``TaskTable.tids``) — so the replay with ``nr_workers > 1``
    predicts what lane parallelism would buy from real measurements: the
    first step of validating the simulator beyond one worker (ROADMAP).
    Tasks that lowered to no items (virtual tasks) replay at zero cost.
    Costs are restored afterwards, as in :func:`replay_round_times`."""
    item_tids = [int(t) for t in item_tids]
    item_times = [float(t) for t in item_times]
    if len(item_tids) != len(item_times):
        raise ValueError(
            f"{len(item_times)} item times for {len(item_tids)} items")
    old_costs = list(sched._tcost)
    costs = [0.0] * len(old_costs)
    for tid, dt in zip(item_tids, item_times):
        if not 0 <= tid < len(costs):
            raise ValueError(f"item task id {tid} out of range")
        costs[tid] += dt
    try:
        sched.set_costs(costs)
        sched.prepare()
        return simulate(sched, nr_workers, overhead=overhead)
    finally:
        sched.set_costs(old_costs)
        sched.prepare()


def scaling_curve(make_sched, worker_counts, overhead: float = 0.0):
    """Run ``simulate`` for each worker count; ``make_sched(n)`` must return
    a fresh prepared QSched with n queues.  Returns list of
    (n, makespan, speedup, efficiency) using the 1-worker makespan as T1."""
    rows = []
    t1 = None
    for n in worker_counts:
        res = simulate(make_sched(n), n, overhead=overhead)
        if t1 is None:
            t1 = res.makespan if n == 1 else res.total_cost
        rows.append((n, res.makespan, t1 / res.makespan,
                     t1 / (n * res.makespan)))
    return rows
