"""ExecutionPlan: the shared lowering layer over a prepared QSched graph.

``lower()`` partitions any prepared graph into *typed, conflict-free,
batchable rounds*: every task in a round has all dependencies in strictly
earlier rounds, no two tasks in a round lock overlapping resource subtrees,
and within a round tasks are grouped by task type so same-type groups can
execute as one vmapped kernel call.  Each round also carries a lane
assignment (resource-ownership affinity + greedy load balancing — the
paper's cache-affinity / work-stealing analogues at schedule time).

This is the single lowering shared by the QR app, Barnes-Hut, and the
pipeline synthesizer; executing a plan needs only a *batch-spec registry*:

    registry = {TASK_TYPE: BatchSpec(run_one=..., run_batch=...)}
    lower(sched, nr_lanes=8).execute(sched, registry)

``run_batch`` (optional) receives all of a round's same-type payloads at
once — stack the operands, call the vmapped kernel, scatter back.  Types
without a ``run_batch`` fall back to per-task ``run_one``.

Plans are cached keyed by the graph's structural hash (CSR arrays + costs +
weights + resource forest/ownership), so trainer/serving loops that rebuild
an identical graph every step skip re-lowering entirely.  The lowering
itself runs over the compiled CSR arrays: when every topo level is
internally conflict-free (QR, pipeline) one vectorized validation pass
emits the Kahn levels as the rounds directly; otherwise a greedy loop with
vectorized ready-set bookkeeping (``csr_gather`` + ``bincount``) and a flat
check-and-claim lock state over precomputed ancestor chains packs rounds
exactly like the runtime protocol would.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

from .arrays import csr_gather
from .graph import FLAG_VIRTUAL, QSched

_PLAN_CACHE: "Dict[Tuple[str, int, Optional[int]], ExecutionPlan]" = {}
_PLAN_CACHE_MAX = 64
# exact-count cache accounting lives on the metrics registry
# (DESIGN.md §Observability); plan_cache_info() keeps the dict shape the
# serving tests assert against
_CACHE_HITS = _metrics.get_registry().counter("plan.cache.hits")
_CACHE_MISSES = _metrics.get_registry().counter("plan.cache.misses")


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _CACHE_HITS.reset()
    _CACHE_MISSES.reset()


def plan_cache_info() -> Dict[str, int]:
    """Cache occupancy plus hit/miss counters since the last
    ``clear_plan_cache``.  The counters are how the serving tier asserts
    its compiled-module-registry behaviour: admission/decode rounds with
    an already-seen batch shape must be cache hits (``tests/test_serve.py``
    plan-cache regression)."""
    return {"entries": len(_PLAN_CACHE), "max": _PLAN_CACHE_MAX,
            "hits": _CACHE_HITS.value, "misses": _CACHE_MISSES.value}


@dataclass(frozen=True)
class BatchSpec:
    """How one task type executes inside a plan round.

    ``run_one(tid, data)`` executes a single task; ``run_batch(tids, datas)``
    (optional) executes a whole same-type group — it is only used when the
    group has at least ``min_batch`` tasks.

    ``encode`` (optional) is the *device* lowering of the same type: it maps
    one task to integer descriptor rows ``[(engine_type, arg0, ...), ...]``
    for the ``repro.engine`` task tables (DESIGN.md §Engine).  One registry
    therefore describes a task family for both execution paths — the
    host-dispatched round executor below and the device-resident engine.
    """
    run_one: Callable[[int, Any], None]
    run_batch: Optional[Callable[[Sequence[int], Sequence[Any]], None]] = None
    min_batch: int = 2
    encode: Optional[Callable[[int, Any], Sequence[Tuple[int, ...]]]] = None


@dataclass(frozen=True)
class TypedBatch:
    ttype: int
    tids: Tuple[int, ...]


@dataclass(frozen=True)
class PlanRound:
    tids: Tuple[int, ...]                 # weight-descending
    batches: Tuple[TypedBatch, ...]       # grouped by type, type-ascending
    lanes: Tuple[Tuple[int, ...], ...]    # lane -> ordered task ids


@dataclass
class ExecutionPlan:
    """A lowered schedule: conflict-free rounds of typed batches.

    The plan stores only task *ids* — payloads are read from the scheduler
    at execution time, so one cached plan serves every structurally
    identical graph (trainer loops rebuilding the same graph each step).
    """
    rounds: List[PlanRound]
    nr_lanes: int
    nr_tasks: int
    structural_hash: str
    stats: Dict[str, Any] = field(default_factory=dict)

    @property
    def nr_rounds(self) -> int:
        return len(self.rounds)

    def check_compatible(self, sched: QSched) -> None:
        """Refuse to pair this plan with a structurally different graph.

        When the plan carries a structural hash (cached lowerings), the
        scheduler must hash identically — executing a plan against a graph
        with different dependencies/conflicts would silently violate them.
        Shared by ``execute`` and the engine table lowering
        (``repro.engine.descriptors``)."""
        if sched.nr_tasks != self.nr_tasks:
            raise ValueError(
                f"plan lowered for {self.nr_tasks} tasks, scheduler has "
                f"{sched.nr_tasks}")
        if self.structural_hash and sched.structural_hash() != self.structural_hash:
            raise ValueError(
                "plan was lowered for a structurally different graph "
                "(structural hash mismatch)")

    def execute(self, sched: QSched,
                registry: Mapping[int, BatchSpec]) -> None:
        """Run every round's typed batches through the registry.  Virtual
        tasks are scheduled but never passed to a spec (FLAG_VIRTUAL)."""
        self.check_compatible(sched)
        datas = sched._tdata
        flags = sched._tflags
        for rnd in self.rounds:
            for tb in rnd.batches:
                tids = [t for t in tb.tids if not flags[t] & FLAG_VIRTUAL]
                if not tids:
                    continue      # all-virtual batches need no BatchSpec
                spec = registry.get(tb.ttype)
                if spec is None:
                    raise KeyError(
                        f"no BatchSpec registered for task type {tb.ttype}")
                if spec.run_batch is not None and len(tids) >= spec.min_batch:
                    spec.run_batch(tids, [datas[t] for t in tids])
                else:
                    run_one = spec.run_one
                    for t in tids:
                        run_one(t, datas[t])

    def run(self, sched: QSched, registry: Mapping[int, "BatchSpec"],
            backend: str = "rounds", *, nr_workers: int = 1,
            engine: Any = None) -> None:
        """Execute this plan on a registered execution backend
        (``core.backends``): ``rounds`` dispatches the typed batches on
        the host, ``engine`` ships descriptor tables to the device
        megakernel, ``sequential``/``threaded`` drain the scheduler
        directly (the plan is ignored but capability-checked)."""
        from .backends import run_plan        # late: backends imports plan
        run_plan(sched, registry, backend, nr_workers=nr_workers,
                 engine=engine, plan=self)


def color_phases(accesses: Sequence[Tuple[Sequence, Sequence]]) -> List[int]:
    """Write-coloring pass: split one round's ordered work items into
    *sub-phases* safe for a parallel walk (DESIGN.md §Engine, "Ragged
    tables & grid walk").

    ``accesses[i]`` is ``(reads, writes)`` — hashable state-row keys item
    ``i`` loads from / stores to.  Conflict-free rounds guarantee that no
    two *tasks* of a round touch overlapping locked resource subtrees, but
    a single task may expand into several descriptor rows that
    read-modify-write the same state row (Barnes-Hut ``acc[leaf] += …``
    chunks, pipeline grad-buffer accumulation), and ``use``-shared state
    may be read by one item while another rewrites it.  Those item pairs
    must not execute concurrently.

    The pass is an order-preserving barrier coloring: items are scanned in
    slab order and a new phase opens exactly when an item conflicts with
    the phase being filled (its writes intersect the phase's reads or
    writes, or its reads intersect the phase's writes).  Phases are
    therefore *contiguous* slices of the original order, items that share
    a destination keep their relative order across phases (accumulation
    order — and hence bit patterns — match the sequential walk), and
    within a phase no two items touch a common state row, so the engine
    may execute a phase's items in any order or in parallel
    (property-tested in ``tests/test_engine_properties.py``).

    Returns the phase boundaries as offsets into ``accesses``
    (``[0, …, len(accesses)]``); ``len(result) - 1`` is the phase count
    (0 for an empty round)."""
    bounds: List[int] = [0]
    if not accesses:
        return bounds
    with _trace.span("plan.color_phases", items=len(accesses)):
        cur_reads: set = set()
        cur_writes: set = set()
        for i, (reads, writes) in enumerate(accesses):
            r, w = set(reads), set(writes)
            conflict = bool((cur_writes & (r | w)) or (w & cur_reads))
            if conflict and i > bounds[-1]:
                bounds.append(i)
                cur_reads, cur_writes = set(), set()
            cur_reads |= r
            cur_writes |= w
        bounds.append(len(accesses))
    return bounds


def lower(sched: QSched, nr_lanes: int,
          max_tasks_per_round: Optional[int] = None,
          cache: bool = True) -> ExecutionPlan:
    """Lower a (prepared) graph into an ExecutionPlan.  Cached by the
    graph's structural hash — identical structure+costs+ownership reuse the
    existing plan without re-lowering."""
    if not sched._is_prepared():
        sched.prepare()
    shash = sched.structural_hash() if cache else ""
    if cache:
        key = (shash, nr_lanes, max_tasks_per_round)
        hit = _PLAN_CACHE.get(key)
        if hit is not None:
            _PLAN_CACHE.pop(key)       # LRU: refresh on hit
            _PLAN_CACHE[key] = hit
            _CACHE_HITS.inc()
            return hit
        _CACHE_MISSES.inc()
    with _trace.span("plan.lower", tasks=sched.nr_tasks,
                     nr_lanes=nr_lanes) as sp:
        plan = _lower(sched, nr_lanes, max_tasks_per_round, shash)
        sp.args["rounds"] = plan.nr_rounds
    if cache:
        if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
            _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
        _PLAN_CACHE[key] = plan
    return plan


def _ancestor_chains(parents: List[int]) -> List[Tuple[int, ...]]:
    chains: List[Tuple[int, ...]] = []
    for r in range(len(parents)):
        out = []
        u = parents[r]
        while u != -1:
            out.append(u)
            u = parents[u]
        chains.append(tuple(out))
    return chains


def _affinity_prefs(g, nr_lanes: int, owners: List[int]) -> List[int]:
    """Per-task lane preference: the owner of the task's first locked (else
    first used) resource under the ownership map at lowering time, -1 when
    that maps to no lane.  One vectorized pass; the map is static for the
    whole lowering (the paper's initial tile/cell → queue assignment), while
    runtime executors keep the dynamic re-owning of §3.4."""
    n = g.n
    owners_arr = np.asarray(owners, dtype=np.int64)
    lp, li = g.locks_indptr, g.locks_indices
    up, ui = g.uses_indptr, g.uses_indices
    first = np.full(n, -1, dtype=np.int64)
    if ui.size:
        has_use = up[1:] > up[:-1]
        first[has_use] = ui[up[:-1][has_use]]
    if li.size:
        has_lock = lp[1:] > lp[:-1]
        first[has_lock] = li[lp[:-1][has_lock]]   # locks take precedence
    pref = np.full(n, -1, dtype=np.int64)
    sel = first >= 0
    pref[sel] = owners_arr[first[sel]]
    pref[(pref < 0) | (pref >= nr_lanes)] = -1
    return pref.tolist()


def _balance_round(chosen: List[int], pref: List[int], cost: List[float],
                   nr_lanes: int) -> Tuple[Tuple[int, ...], ...]:
    """Greedy load balance of one round (``chosen`` is weight-descending):
    a task takes its preferred lane unless it is unset or already holds more
    than 2× the round's mean per-lane cost, in which case it spills to the
    currently least-loaded lane (the schedule-time work-stealing analogue).
    The mean-based overload cap is a constant per round, so affinity
    assignments cost O(1) and only actual spills scan for the minimum."""
    lanes: List[List[int]] = [[] for _ in range(nr_lanes)]
    load = [0.0] * nr_lanes
    cap = 2.0 * sum(cost[t] for t in chosen) / nr_lanes + 1e-12
    for tid in chosen:
        lane = pref[tid]
        if lane < 0 or load[lane] > cap:
            lane = load.index(min(load))  # steal: owner lane overloaded
        lanes[lane].append(tid)
        load[lane] += cost[tid]
    return tuple(tuple(l) for l in lanes)


def _batches_of(chosen: List[int], types: List[int]) -> Tuple[TypedBatch, ...]:
    by_type: Dict[int, List[int]] = {}
    for tid in chosen:
        by_type.setdefault(types[tid], []).append(tid)
    return tuple(TypedBatch(tt, tuple(tids))
                 for tt, tids in sorted(by_type.items()))


def _level_rounds(sched: QSched, g, nr_lanes: int, cap: int,
                  types: List[int], cost: List[float], pref: List[int],
                  flat_forest: bool):
    """Shortcut: when every topo level is internally conflict-free, the
    greedy round construction provably reproduces the Kahn levels computed
    by ``prepare()`` — validate that property in one vectorized pass over
    the locks COO and emit all rounds without iterating the ready set.
    Returns None when some level carries a conflict (or the cap binds) and
    the general greedy loop must run."""
    n = g.n
    sizes = np.diff(g.level_ptr)
    if sizes.size and int(sizes.max()) > cap:
        return None
    lvl_of = np.empty(n, dtype=np.int64)
    lvl_of[g.order] = np.repeat(np.arange(sizes.size, dtype=np.int64), sizes)
    li = g.locks_indices
    if li.size:
        task_per = np.repeat(np.arange(n, dtype=np.int64),
                             np.diff(g.locks_indptr))
        keys = lvl_of[task_per] * g.nres + li
        skeys = np.sort(keys)
        if bool((skeys[1:] == skeys[:-1]).any()):
            return None          # two tasks in one level lock the same res
        if not flat_forest:
            anc = _ancestor_chains(sched._res_parent)
            anc_indptr = np.zeros(g.nres + 1, dtype=np.int64)
            np.cumsum([len(c) for c in anc], out=anc_indptr[1:])
            anc_indices = np.asarray([a for c in anc for a in c],
                                     dtype=np.int64)
            anc_deg = anc_indptr[li + 1] - anc_indptr[li]
            anc_flat = csr_gather(anc_indptr, anc_indices, li)
            if anc_flat.size:
                akeys = (np.repeat(lvl_of[task_per], anc_deg) * g.nres
                         + anc_flat)
                pos = np.searchsorted(skeys, akeys)
                pos = np.minimum(pos, skeys.size - 1)
                if bool((skeys[pos] == akeys).any()):
                    return None  # locked res + ancestor within one level
    # round order the greedy loop would produce: level, then weight
    # descending, ties by ascending id (lexsort is stable)
    perm_list = np.lexsort((-sched._weight, lvl_of)).tolist()
    rounds: List[PlanRound] = []
    off = 0
    for sz in sizes.tolist():
        chosen = perm_list[off:off + sz]
        off += sz
        rounds.append(PlanRound(
            tuple(chosen), _batches_of(chosen, types),
            _balance_round(chosen, pref, cost, nr_lanes)))
    return rounds


def _lower(sched: QSched, nr_lanes: int, cap: Optional[int],
           shash: str) -> ExecutionPlan:
    g = sched.graph
    n = g.n
    weight = sched._weight.tolist()
    types = sched._ttype
    cost = sched._tcost
    cap = cap or n
    flat_forest = all(p == -1 for p in sched._res_parent)
    pref = _affinity_prefs(g, nr_lanes, sched._res_owner)

    level_rounds = _level_rounds(sched, g, nr_lanes, cap, types, cost,
                                 pref, flat_forest)
    if level_rounds is not None:
        return _finish_plan(level_rounds, nr_lanes, n, shash,
                            fastpath_rounds=len(level_rounds),
                            level_shortcut=True)

    wait = g.wait0.copy()
    ready: List[int] = np.flatnonzero(g.wait0 == 0).tolist()
    ready.sort(key=weight.__getitem__, reverse=True)
    locks = g.locks_list
    anc = _ancestor_chains(sched._res_parent)
    # flat lock state, reset incrementally between rounds (paper §3.2
    # semantics: lock excludes ancestors and descendants via hold counts)
    locked = bytearray(g.nres)
    hold = [0] * g.nres

    rounds: List[PlanRound] = []
    done = 0
    fastpath_rounds = 0
    while done < n:
        # Fast path: check the whole ready set for mutual conflict-freedom
        # in one vectorized pass (no duplicate locked resource, no locked
        # resource in another's ancestor chain).
        chosen: Optional[List[int]] = None
        skipped: List[int] = []
        if len(ready) <= cap:
            ls_flat = csr_gather(g.locks_indptr, g.locks_indices,
                                 np.asarray(ready, dtype=np.int64))
            uniq = np.unique(ls_flat)
            ok = uniq.size == ls_flat.size
            if ok and not flat_forest and uniq.size:
                mask = np.zeros(g.nres, dtype=bool)
                mask[uniq] = True
                anc_flat = np.asarray(
                    [a for r in uniq.tolist() for a in anc[r]],
                    dtype=np.int64)
                ok = not (anc_flat.size and bool(mask[anc_flat].any()))
            if ok:
                chosen = ready
                fastpath_rounds += 1
        if chosen is None:
            chosen = []
            for tid in ready:
                if len(chosen) >= cap:
                    skipped.append(tid)
                    continue
                ls = locks[tid]
                ok = True
                taken = 0
                for r in ls:
                    if locked[r] or hold[r]:
                        ok = False
                        break
                    locked[r] = 1
                    for a in anc[r]:
                        if locked[a]:
                            ok = False
                            locked[r] = 0
                            break
                        hold[a] += 1
                    if not ok:
                        # roll back the partial ancestor holds of r
                        for a in anc[r]:
                            if locked[a]:
                                break
                            hold[a] -= 1
                        break
                    taken += 1
                if ok:
                    chosen.append(tid)
                else:
                    for r in ls[:taken]:      # all-or-nothing rollback
                        locked[r] = 0
                        for a in anc[r]:
                            hold[a] -= 1
                    skipped.append(tid)
            if not chosen:
                raise RuntimeError(
                    "static schedule stalled (conflict deadlock?)")
            # release this round's lock state for the next one
            for tid in chosen:
                for r in locks[tid]:
                    locked[r] = 0
                    for a in anc[r]:
                        hold[a] -= 1
        rounds.append(PlanRound(
            tuple(chosen), _batches_of(chosen, types),
            _balance_round(chosen, pref, cost, nr_lanes)))
        done += len(chosen)
        # release dependencies (vectorized over the whole round)
        newly: List[int] = []
        succ = csr_gather(g.unlocks_indptr, g.unlocks_indices,
                          np.asarray(chosen, dtype=np.int64))
        if succ.size:
            dec = np.bincount(succ, minlength=n)
            wait -= dec
            newly = np.flatnonzero((wait == 0) & (dec > 0)).tolist()
        ready = skipped + newly
        ready.sort(key=weight.__getitem__, reverse=True)

    return _finish_plan(rounds, nr_lanes, n, shash,
                        fastpath_rounds=fastpath_rounds,
                        level_shortcut=False)


def _finish_plan(rounds: List[PlanRound], nr_lanes: int, n: int, shash: str,
                 fastpath_rounds: int, level_shortcut: bool) -> ExecutionPlan:
    batched = sum(1 for rnd in rounds for tb in rnd.batches if len(tb.tids) > 1)
    return ExecutionPlan(
        rounds=rounds, nr_lanes=nr_lanes, nr_tasks=n, structural_hash=shash,
        stats={"rounds": len(rounds), "tasks": n,
               "fastpath_rounds": fastpath_rounds,
               "level_shortcut": level_shortcut,
               "multi_task_batches": batched})
