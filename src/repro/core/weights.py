"""Topological ordering, cycle detection and critical-path weights (paper §3.1).

``weight_i = cost_i + max_{j in unlocks_i} weight_j``

computed by traversing the DAG in *reverse* topological order (Kahn 1962),
O(V+E).  The weight of a task is the total cost of the critical path that
starts at it; queues prioritise the largest weight first.
"""

from __future__ import annotations

from collections import deque
from typing import List, Sequence, Tuple


def toposort(n: int, unlocks: Sequence[Sequence[int]]) -> List[int]:
    """Kahn's algorithm over the ``unlocks`` adjacency (A unlocks B == B
    depends on A).  Returns task ids in topological order.  Raises
    ``ValueError`` on a dependency cycle."""
    indeg = [0] * n
    for src in range(n):
        for dst in unlocks[src]:
            indeg[dst] += 1
    q = deque(i for i in range(n) if indeg[i] == 0)
    order: List[int] = []
    while q:
        i = q.popleft()
        order.append(i)
        for j in unlocks[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                q.append(j)
    if len(order) != n:
        cyclic = [i for i in range(n) if indeg[i] > 0]
        raise ValueError(
            f"dependency cycle detected involving {len(cyclic)} tasks "
            f"(e.g. ids {cyclic[:8]})"
        )
    return order


def critical_path_weights(
    n: int, unlocks: Sequence[Sequence[int]], costs: Sequence[float]
) -> Tuple[List[float], List[int]]:
    """Return (weights, toposort order).  weights follow the paper's
    recurrence; the order is reused by callers (e.g. wait-counter init)."""
    order = toposort(n, unlocks)
    weights = [0.0] * n
    for i in reversed(order):
        w = 0.0
        for j in unlocks[i]:
            if weights[j] > w:
                w = weights[j]
        weights[i] = costs[i] + w
    return weights, order


def critical_path_length(
    n: int, unlocks: Sequence[Sequence[int]], costs: Sequence[float]
) -> float:
    """Length of the longest cost-weighted path in the DAG — the lower bound
    on makespan for any number of workers."""
    if n == 0:
        return 0.0
    weights, _ = critical_path_weights(n, unlocks, costs)
    return max(weights)
