"""Execution backends: one registry for every way a task graph can run.

The paper's central claim is that ONE scheduler core serves heterogeneous
workloads without per-workload executor code.  This module is where that
claim lives at the dispatch layer: a :class:`Backend` knows how to drive a
``(sched, plan, registry)`` triple, backends register under their mode
string, and every caller — the QR app, Barnes-Hut, the pipeline
synthesizer, benchmarks — executes through ``get_backend(mode).run(...)``
(or the :func:`run_plan` convenience that also lowers the plan when the
backend needs one).  No ``if mode == ...`` ladders anywhere above core.

What a backend needs is discoverable, not hard-coded per app:

* the host backends (``sequential``, ``threaded``, ``rounds``) need each
  task type's ``BatchSpec.run_one`` (plus ``run_batch`` for round
  batching);
* the ``engine`` backend additionally needs per-type device encoders
  (``BatchSpec.encode``, DESIGN.md §Engine) and family-level
  :class:`EngineHooks` (which megakernel interprets the rows, which state
  buffers it owns).  ``Backend.supports(plan, registry, engine)`` reports
  whether a lowered plan can run on a backend *before* running it, so
  callers can probe capability instead of guessing.

Capability flags instead of mode strings: ``needs_plan`` (the backend
executes a lowered ExecutionPlan), ``concurrent`` (task bodies run on
worker threads — shared state must be thread-mutable), ``device_resident``
(task bodies run inside a fused device kernel — state must be device
arrays).  Apps branch on these attributes, never on the mode name.
DESIGN.md §Backends.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Mapping, Optional, Sequence,
                    Tuple)

from .executors import SequentialExecutor, ThreadedExecutor
from .graph import FLAG_VIRTUAL, QSched
from .plan import BatchSpec, ExecutionPlan, lower


class BackendUnsupported(ValueError):
    """Raised when a backend cannot execute the given plan/registry."""


@dataclass(frozen=True)
class EngineHooks:
    """Family-level configuration the ``engine`` backend needs beyond the
    per-type ``BatchSpec.encode`` rows: which megakernel interprets the
    descriptor rows, which state rows each row touches, and which device
    buffers the kernel owns.

    ``row_access(row) -> (reads, writes)`` maps one descriptor row to the
    hashable state-row keys it loads from / stores to — the input to the
    write-coloring pass that splits each round into grid-parallel-safe
    sub-phases (``core.plan.color_phases``, DESIGN.md §Engine "Ragged
    tables & grid walk").  ``statics``/``buffers`` are zero-arg factories
    (called once per run) so hooks stay cheap to build — device stacking
    happens only when the engine actually executes.
    ``writeback(buffers)`` scatters the final device state back into the
    caller's host-side structures.
    """
    arg_width: int
    round_fn: Callable   # (desc, phase_bounds, statics, buffers) -> buffers
    statics: Callable[[], Tuple]
    buffers: Callable[[], Tuple]
    writeback: Callable[[Tuple], None]
    row_access: Optional[Callable] = None
    fuse_rounds: bool = False
    donate: Optional[bool] = None


def _plan_types(plan: ExecutionPlan, sched: QSched) -> Sequence[int]:
    """Task types with at least one non-virtual task in the plan."""
    flags = sched._tflags
    seen = []
    for rnd in plan.rounds:
        for tb in rnd.batches:
            if tb.ttype in seen:
                continue
            if any(not flags[t] & FLAG_VIRTUAL for t in tb.tids):
                seen.append(tb.ttype)
    return seen


class Backend:
    """Base execution backend.  Subclasses set the capability flags and
    implement ``run``; ``supports`` defaults to requiring a ``run_one``
    per non-virtual task type (every backend dispatches through the same
    BatchSpec registry)."""

    name: str = "?"
    needs_plan: bool = False      # run() consumes a lowered ExecutionPlan
    concurrent: bool = False      # task bodies run on worker threads
    device_resident: bool = False  # task bodies run inside a fused kernel

    def supports(self, plan: Optional[ExecutionPlan], sched: QSched,
                 registry: Mapping[int, BatchSpec],
                 engine: Optional[EngineHooks] = None) -> bool:
        if plan is None:
            return True
        return all(t in registry for t in _plan_types(plan, sched))

    def compiled_kernels(self) -> bool:
        """Capability probe: True when this backend executes its device
        kernels natively compiled for the local runtime (as opposed to
        host dispatch or Pallas interpret mode).  Callers use it to pick
        between a kernel-resident fast path and a jitted fallback — the
        serving tier selects its paged-attention decode path this way
        (DESIGN.md §Serving) — instead of sniffing platform names."""
        return False

    def run(self, sched: QSched, plan: Optional[ExecutionPlan],
            registry: Mapping[int, BatchSpec], *, nr_workers: int = 1,
            engine: Optional[EngineHooks] = None) -> None:
        raise NotImplementedError

    def check(self, plan, sched, registry, engine) -> None:
        if not self.supports(plan, sched, registry, engine):
            raise BackendUnsupported(
                f"backend {self.name!r} cannot execute this plan "
                f"(missing run_one/encode hooks or engine family hooks)")


class SequentialBackend(Backend):
    """One worker drains the scheduler in priority order, calling each
    type's ``run_one``.  Task bodies may operate on traced JAX values, so
    wrapping the call in ``jax.jit`` turns the whole graph into a single
    XLA program ordered by the QuickSched schedule."""

    name = "sequential"

    def run(self, sched, plan, registry, *, nr_workers=1, engine=None):
        del plan, nr_workers, engine
        SequentialExecutor(sched).run_registry(registry)


class ThreadedBackend(Backend):
    """The paper's pthread-pool analogue: ``nr_workers`` threads pull from
    per-worker queues under the real lock protocol.  Shared state must
    tolerate concurrent task bodies (``concurrent=True``) — the resource
    locks are the only thing preventing lost updates."""

    name = "threaded"
    concurrent = True

    def run(self, sched, plan, registry, *, nr_workers=1, engine=None):
        del plan, engine
        ThreadedExecutor(sched, nr_workers).run_registry(registry)


class RoundsBackend(Backend):
    """Bulk-synchronous conflict-free rounds via ``ExecutionPlan.execute``:
    same-type groups within a round batch through ``run_batch`` (stack →
    one vmapped kernel → scatter), everything else through ``run_one``."""

    name = "rounds"
    needs_plan = True

    def run(self, sched, plan, registry, *, nr_workers=1, engine=None):
        del nr_workers, engine
        plan.execute(sched, registry)


class EngineBackend(Backend):
    """Device-resident execution (DESIGN.md §Engine): the plan lowers to
    descriptor task tables through the registry's ``encode`` hooks and the
    whole plan runs as one jitted dispatch of the family megakernel."""

    name = "engine"
    needs_plan = True
    device_resident = True

    def supports(self, plan, sched, registry, engine=None):
        if engine is None or plan is None:
            return False
        return all(t in registry and registry[t].encode is not None
                   for t in _plan_types(plan, sched))

    def compiled_kernels(self) -> bool:
        # the engine's megakernels (and the serving tier's paged-attention
        # kernel) compile natively only on TPU; everywhere else Pallas
        # runs in interpret mode and jitted XLA fallbacks win
        import jax
        return jax.default_backend() == "tpu"

    def run(self, sched, plan, registry, *, nr_workers=1, engine=None):
        del nr_workers
        # engine lives above core in the layer diagram; import lazily so
        # core carries no hard dependency on the Pallas stack
        from repro.engine import execute_plan, lower_tables
        tables = lower_tables(plan, sched, registry,
                              arg_width=engine.arg_width,
                              row_access=engine.row_access)
        out = execute_plan(tables, engine.round_fn, engine.statics(),
                           engine.buffers(), fuse_rounds=engine.fuse_rounds,
                           donate=engine.donate)
        engine.writeback(out)


_BACKENDS: Dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    """Register (or replace) a backend under its ``name``."""
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(mode: str) -> Backend:
    try:
        return _BACKENDS[mode]
    except KeyError:
        raise ValueError(
            f"unknown execution backend {mode!r}; registered: "
            f"{sorted(_BACKENDS)}") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


register_backend(SequentialBackend())
register_backend(ThreadedBackend())
register_backend(RoundsBackend())
register_backend(EngineBackend())


def run_plan(sched: QSched, registry: Mapping[int, BatchSpec],
             mode: str = "sequential", *, nr_workers: int = 1,
             nr_lanes: Optional[int] = None,
             engine: Optional[EngineHooks] = None,
             plan: Optional[ExecutionPlan] = None) -> Optional[ExecutionPlan]:
    """THE unified dispatch: look the backend up, lower the plan if the
    backend needs one (and none was passed), check capability, run.
    Returns the plan that was executed (None for plan-free backends) so
    callers can inspect rounds/stats."""
    backend = get_backend(mode)
    if backend.needs_plan and plan is None:
        plan = lower(sched, nr_lanes or max(nr_workers, 1))
    backend.check(plan, sched, registry, engine)
    backend.run(sched, plan, registry, nr_workers=nr_workers, engine=engine)
    return plan
