"""Max-heap task queue (paper §3.3).

Tasks are kept in a binary max-heap keyed by task weight.  ``get`` walks the
heap array *in index order* (the paper's compromise: the k-th entry of n is
heavier than at least floor(n/k)-1 others) and returns the first task whose
resources can all be locked.  Removal restores the heap invariant with a
sift-down *and* sift-up (the paper only trickles down; sifting both ways
keeps the invariant exact at the same O(log n) cost — noted in DESIGN.md).
"""

from __future__ import annotations

import threading
from typing import Callable, List, Optional


class TaskQueue:
    def __init__(self, weights: List[float], threaded: bool = False):
        self._weights = weights  # shared, indexed by task id
        self._heap: List[int] = []
        self._mutex = threading.Lock() if threaded else None

    def __len__(self) -> int:
        return len(self._heap)

    # -- heap plumbing ------------------------------------------------------
    def _sift_up(self, k: int) -> int:
        h, w = self._heap, self._weights
        while k > 0:
            p = (k - 1) >> 1
            if w[h[p]] >= w[h[k]]:
                break
            h[p], h[k] = h[k], h[p]
            k = p
        return k

    def _sift_down(self, k: int) -> int:
        h, w = self._heap, self._weights
        n = len(h)
        while True:
            l, r = 2 * k + 1, 2 * k + 2
            big = k
            if l < n and w[h[l]] > w[h[big]]:
                big = l
            if r < n and w[h[r]] > w[h[big]]:
                big = r
            if big == k:
                return k
            h[big], h[k] = h[k], h[big]
            k = big

    # -- queue API (paper queue_put / queue_get) ----------------------------
    def put(self, tid: int) -> None:
        if self._mutex:
            with self._mutex:
                self._heap.append(tid)
                self._sift_up(len(self._heap) - 1)
        else:
            self._heap.append(tid)
            self._sift_up(len(self._heap) - 1)

    def get(self, try_lock: Callable[[int], bool]) -> Optional[int]:
        """Scan the heap in index order; ``try_lock(tid)`` attempts to lock
        the task's resources (all-or-nothing).  Returns the first lockable
        task id, removing it from the heap, or None."""
        if self._mutex:
            with self._mutex:
                return self._get(try_lock)
        return self._get(try_lock)

    def _get(self, try_lock: Callable[[int], bool]) -> Optional[int]:
        h = self._heap
        for k in range(len(h)):
            tid = h[k]
            if try_lock(tid):
                last = h.pop()
                if k < len(h):
                    h[k] = last
                    if self._sift_down(k) == k:
                        self._sift_up(k)
                return tid
        return None

    def peek_weights(self) -> List[float]:
        return [self._weights[t] for t in self._heap]

    def check_heap(self) -> bool:
        h, w = self._heap, self._weights
        return all(
            w[h[(k - 1) >> 1]] >= w[h[k]] for k in range(1, len(h))
        )
