"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8 experts
[arXiv:2412.19437; hf].  MTP head not implemented (DESIGN.md
§Arch-applicability).  Optimizer: adafactor."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,           # dense first layers hidden
    vocab=129280,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=256,
    experts_per_tok=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    attn_chunk=2048,
)
