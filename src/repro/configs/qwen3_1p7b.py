"""qwen3-1.7b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    attn_chunk=2048,
)
