"""internvl2-76b — InternLM2 backbone; InternViT frontend is a stub:
input_specs() provides projected patch embeddings
[arXiv:2404.16821; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    n_vis_tokens=256,
    attn_chunk=2048,
)
