"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2; unverified].  Optimizer: adafactor (EXPERIMENTS §Dry-run
memory note)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=16384,           # dense first layer hidden
    vocab=163840,
    head_dim=128,
    n_experts=384,
    experts_per_tok=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=1,
    attn_chunk=2048,
)
