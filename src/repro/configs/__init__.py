"""Architecture registry: one module per assigned architecture
(``--arch <id>`` in the launchers)."""

from importlib import import_module
from typing import Dict

from repro.models.config import ModelConfig

_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "starcoder2-7b": "starcoder2_7b",
    "granite-8b": "granite_8b",
    "qwen3-1.7b": "qwen3_1p7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-tiny": "whisper_tiny",
    "internvl2-76b": "internvl2_76b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[name]}").CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}
