"""zamba2-7b — hybrid Mamba2 trunk + shared attention blocks
[arXiv:2411.15242; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_version=2,
    ssm_state=64,
    ssm_headdim=64,
    expand=2,
    d_conv=4,
    shared_attn_every=6,
    n_shared_blocks=2,
    attn_chunk=2048,
)
