"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 200 --workdir /tmp/run1

``--resume`` continues from the latest checkpoint in workdir (the loop also
auto-resumes if one exists).  ``--fail-at`` injects a failure (fault-
tolerance drill).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="auto",
                    choices=["auto", "adamw", "adafactor"])
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome/Perfetto trace of the run "
                         "(per-step train.step spans)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.trainer.loop import run_training

    if args.trace:
        from repro.obs import enable as obs_enable
        obs_enable()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    _, _, history = run_training(
        cfg, args.workdir, args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, lr=args.lr,
        optimizer=args.optimizer, ckpt_every=args.ckpt_every,
        fail_at_step=args.fail_at, seed=args.seed)
    first = history[0][1] if history else float("nan")
    last = history[-1][1] if history else float("nan")
    print(f"done: {len(history)} steps, loss {first:.4f} -> {last:.4f}")
    if args.trace:
        from repro.obs import write_chrome_trace
        info = write_chrome_trace(args.trace)
        print(f"trace: {args.trace} ({info['events']} events) — open in "
              f"https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
