"""Serving drivers.

Static batch (the original loop): prefill a batch of prompts, then decode
with a donated KV/state cache until the slowest member finishes.

    PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
        --reduced --batch 4 --prompt-len 16 --new-tokens 32

Continuous batching (``--continuous``): the ``repro.serve`` service — a
paged block pool, admission lowered as a QuickSched conflict round, and
engine-backed batched decode with per-step join/leave.  ``--new-tokens``
becomes the *maximum* budget; per-request budgets are drawn ragged so
requests actually retire mid-stream.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --continuous --batch 4 --prompt-len 8 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time


def _continuous_main(args) -> None:
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import lm
    from repro.obs import enable as obs_enable, write_chrome_trace
    from repro.serve import FaultPlan, GenerateService, QueueFull, \
        SamplingParams

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.trace:
        obs_enable()
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    page = 8
    max_seq = -(-(args.prompt_len + args.new_tokens - 1) // page) * page
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, seed=args.seed)
    faults = None
    if args.chaos_seed is not None:
        faults = FaultPlan.seeded(args.chaos_seed, args.chaos_ticks)
        print(f"chaos: seed={args.chaos_seed} over {args.chaos_ticks} "
              f"ticks -> {faults.summary()}")
    svc = GenerateService(params, cfg, max_batch=args.batch,
                          max_seq=max_seq, page_size=page,
                          decode_path=args.decode_path, sampling=sampling,
                          max_queue=args.max_queue,
                          deadline_ms=args.deadline_ms,
                          guard=not args.no_guard, faults=faults)
    print(f"decode path: {svc.decode_path} (requested {args.decode_path}, "
          f"guard={'on' if svc.guard else 'off'})")
    rng = np.random.default_rng(args.seed)
    n_req = 3 * args.batch
    handles = []
    for _ in range(n_req):
        prompt = rng.integers(0, cfg.vocab, args.prompt_len, dtype=np.int32)
        budget = int(rng.choice([args.new_tokens // 8 or 1,
                                 args.new_tokens // 2 or 1, args.new_tokens]))
        try:
            handles.append(svc.submit(prompt, budget))
        except QueueFull as e:
            print(f"  rejected (queue {e.queue_depth}/{e.max_queue})")
    t0 = time.time()
    svc.run_until_complete()
    dt = time.time() - t0
    done = svc.stats["generated_tokens"]
    print(f"continuous: {len(handles)} requests, {done} tokens in "
          f"{svc.stats['steps']} steps, {dt:.2f}s ({done / dt:.1f} tok/s)")
    print(f"entry points: {svc.compiled_entry_points()}")
    s = svc.stats
    print(f"robustness: retries={s['retries']} "
          f"preemptions={s['preemptions']} rejected={s['rejected']} "
          f"deadline_exceeded={s['deadline_exceeded']} "
          f"cancelled={s['cancelled']} faults_injected={s['faults_injected']}")
    from collections import Counter
    print(f"terminal states: {dict(Counter(h.status for h in handles))}")
    assert all(h.done for h in handles), "a request never reached a terminal state"
    assert svc.pool.allocated == 0, "pages leaked"
    svc.pool.check_invariants()
    if args.trace:
        info = write_chrome_trace(args.trace, registry=svc.metrics)
        print(f"trace: {args.trace} ({info['events']} events, "
              f"{len(info['counter_tracks'])} counter tracks) — open in "
              f"https://ui.perfetto.dev")
    print("greedy continuations (token ids):")
    for h in handles[:4]:
        print(f"  rid={h.rid} n={len(h.generated)}:", h.generated[:16])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="run the repro.serve continuous-batching service")
    ap.add_argument("--decode-path", default="auto",
                    choices=["auto", "kernel", "bounded", "gather"],
                    help="continuous mode: decode round function — auto "
                         "probes the engine backend (paged-attention "
                         "kernel where Pallas compiles natively, bounded "
                         "gather elsewhere); kernel/bounded/gather force "
                         "a path")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="continuous mode: 0 = greedy (default); >0 "
                         "samples with one per-request PRNG stream "
                         "seeded from --seed")
    ap.add_argument("--top-k", type=int, default=0,
                    help="continuous mode: truncate sampling to the k "
                         "highest-probability tokens (0 = full vocab)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="continuous mode: default per-request deadline; "
                         "an active request past it is preempted, its "
                         "pages reclaimed, and retired DEADLINE_EXCEEDED")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="continuous mode: bound the admission queue — "
                         "submissions past the bound are rejected with "
                         "QueueFull instead of growing without limit")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="continuous mode: inject a seeded FaultPlan "
                         "(NaN-poisoned decode rounds, admission "
                         "failures, prefill-cache drops) and assert the "
                         "run still terminates with pages conserved")
    ap.add_argument("--chaos-ticks", type=int, default=32,
                    help="number of service ticks the seeded fault plan "
                         "covers (with --chaos-seed)")
    ap.add_argument("--no-guard", action="store_true",
                    help="continuous mode: disable the post-round "
                         "finiteness guard (and with it retry/degrade/"
                         "preempt-on-fault)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome/Perfetto trace of the run "
                         "(continuous mode: request lifecycles, engine "
                         "launches, pool/queue counter tracks)")
    args = ap.parse_args()
    if args.continuous:
        _continuous_main(args)
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import lm, serving
    from repro.obs import enable as obs_enable, get_tracer, write_chrome_trace
    from repro.trainer.steps import make_serve_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.trace:
        obs_enable()
    max_seq = args.prompt_len + args.new_tokens
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    tokens = jax.random.randint(jax.random.fold_in(key, 1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    extra = {}
    if cfg.family == "vlm":
        extra["vis_embeds"] = jnp.zeros(
            (args.batch, cfg.n_vis_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        extra["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))

    tr = get_tracer()
    t0 = time.time()
    with tr.span("serve.prefill", batch=args.batch, plen=args.prompt_len):
        logits, cache, pos = serving.prefill(params, cfg, tokens, extra=extra)
        jax.block_until_ready(logits)
    # pad the prompt-length cache out to max_seq (attention caches only)
    plen = args.prompt_len + (cfg.n_vis_tokens if cfg.family == "vlm" else 0)

    def pad(a):
        if a.ndim >= 4 and a.shape[2] == plen:
            padding = [(0, 0)] * a.ndim
            padding[2] = (0, max_seq - args.prompt_len)
            return jnp.pad(a, padding)
        return a

    cache = jax.tree.map(pad, cache)
    print(f"prefill {args.batch}×{args.prompt_len}: {time.time() - t0:.2f}s")

    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    t0 = time.time()
    with tr.span("serve.decode", batch=args.batch, tokens=args.new_tokens):
        for i in range(args.new_tokens):
            with tr.span("serve.decode_step", step=i):
                logits, cache = serve_step(params, cache, tok, pos)
                if tr.enabled:
                    jax.block_until_ready(logits)
            tok = jnp.argmax(logits, -1)[:, None]
            pos = pos + 1
            out.append(tok)
        jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"decode {args.new_tokens} tokens × batch {args.batch}: "
          f"{dt:.2f}s ({args.new_tokens * args.batch / dt:.1f} tok/s)")
    if args.trace:
        info = write_chrome_trace(args.trace)
        print(f"trace: {args.trace} ({info['events']} events) — open in "
              f"https://ui.perfetto.dev")
    ids = jnp.concatenate(out, axis=1)
    print("greedy continuations (token ids):")
    for row in ids[:4]:
        print("  ", list(map(int, row[:16])))


if __name__ == "__main__":
    main()
