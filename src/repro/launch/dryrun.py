import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
  lower + compile the full-size step with production shardings (inputs are
  ShapeDtypeStructs — nothing is allocated), then record
    * compiled.memory_analysis()  — per-device bytes (proves it fits),
    * compiled.cost_analysis()    — per-device HLO flops / bytes,
    * the collective schedule     — op counts + per-device operand bytes
      parsed from compiled.as_text(),
    * depth-extrapolation         — XLA's HloCostAnalysis counts a scanned
      layer body ONCE, so each cell is additionally lowered at two reduced
      depths and the per-layer delta is extrapolated to the full depth
      (flops and collective bytes; verified against an unrolled small model
      in tests/test_dryrun_small.py).

Results are written incrementally as JSON (one file per cell) for
benchmarks/roofline.py.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective op counts and operand bytes (per device, since the
    compiled module is the post-SPMD per-device program)."""
    stats = {c: {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0}
             for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            if f" {c}(" in line or f"{c}-start(" in line:
                matches = list(_SHAPE_RE.finditer(line))
                if not matches:
                    continue
                paren = line.find("(", line.find(c))
                result = [m for m in matches if m.start() < paren]
                operands = [m for m in matches if m.start() >= paren]
                stats[c]["count"] += 1
                stats[c]["operand_bytes"] += sum(
                    _shape_bytes(m) for m in operands)
                stats[c]["result_bytes"] += sum(
                    _shape_bytes(m) for m in result)
                break
    return stats


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(v["operand_bytes"] for v in stats.values())


# -----------------------------------------------------------------------------

def input_specs(cfg, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    from repro.data import batch_specs
    p = SHAPES[shape_name]
    mode = {"train": "train", "prefill": "prefill", "decode": "decode"}[p["kind"]]
    return batch_specs(cfg, p["seq_len"], p["global_batch"], mode=mode)


def depth_variants(cfg) -> Tuple:
    """Two reduced-depth configs preserving family structure, plus the
    per-unit layer count for extrapolation: returns
    (cfg1, cfg2, units1, units2, units_full).  Probes are UNROLLED
    (scan_layers=False) so HloCostAnalysis sees every layer."""
    cfg = dataclasses.replace(cfg, scan_layers=False)
    fam = cfg.family
    if fam == "moe":
        fd = cfg.first_dense_layers
        c1 = dataclasses.replace(cfg, n_layers=fd + 1)
        c2 = dataclasses.replace(cfg, n_layers=fd + 2)
        return c1, c2, 1, 2, cfg.n_layers - fd
    if fam == "hybrid":
        e = cfg.shared_attn_every
        c1 = dataclasses.replace(cfg, n_layers=e)
        c2 = dataclasses.replace(cfg, n_layers=2 * e)
        return c1, c2, 1, 2, cfg.n_layers / e
    if fam == "encdec":
        c1 = dataclasses.replace(cfg, n_layers=1, enc_layers=1)
        c2 = dataclasses.replace(cfg, n_layers=2, enc_layers=2)
        return c1, c2, 1, 2, cfg.n_layers  # enc and dec scale together
    c1 = dataclasses.replace(cfg, n_layers=1)
    c2 = dataclasses.replace(cfg, n_layers=2)
    return c1, c2, 1, 2, cfg.n_layers


def skip_reason(cfg, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: 500k decode needs sub-quadratic "
                "attention (DESIGN.md §Arch-applicability)")
    return None


def build_cell(cfg, shape_name: str, mesh, multi_pod: bool):
    """Returns (jitted_fn, example_args (SDS), in_shardings description)."""
    import functools

    from repro.data import batch_specs
    from repro.dist.sharding import (batch_pspecs, cache_pspecs, opt_pspecs,
                                     param_pspecs, shardings_for)
    from repro.models import lm, serving
    from repro.optim import default_optimizer_for, make_optimizer
    from repro.trainer.steps import (make_prefill_step, make_serve_step,
                                     make_train_step)

    p = SHAPES[shape_name]
    kind = p["kind"]
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(functools.partial(lm.init_params, key, cfg))
    pspecs = param_pspecs(param_shapes, mesh, multi_pod)
    pshard = shardings_for(pspecs, mesh)

    if kind == "train":
        opt_name = default_optimizer_for(cfg)
        train_step, opt_init = make_train_step(cfg, optimizer=opt_name)
        opt_shapes = jax.eval_shape(opt_init, param_shapes)
        ospecs = opt_pspecs(pspecs, opt_shapes, mesh)
        oshard = shardings_for(ospecs, mesh)
        bspecs = batch_specs(cfg, p["seq_len"], p["global_batch"], "train")
        bpspecs = batch_pspecs(bspecs, mesh, multi_pod)
        bshard = shardings_for(bpspecs, mesh)
        fn = jax.jit(train_step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return fn, (param_shapes, opt_shapes, bspecs), {"optimizer": opt_name}

    if kind == "prefill":
        prefill_step = make_prefill_step(cfg)
        bspecs = batch_specs(cfg, p["seq_len"], p["global_batch"], "prefill")
        bpspecs = batch_pspecs(bspecs, mesh, multi_pod)
        bshard = shardings_for(bpspecs, mesh)
        fn = jax.jit(prefill_step, in_shardings=(pshard, bshard))
        return fn, (param_shapes, bspecs), {}

    # decode
    serve_step = make_serve_step(cfg)
    cache_shapes = jax.eval_shape(functools.partial(
        serving.init_cache, cfg, p["global_batch"], p["seq_len"]))
    cspecs = cache_pspecs(cache_shapes, cfg, mesh, multi_pod)
    cshard = shardings_for(cspecs, mesh)
    tok = jax.ShapeDtypeStruct((p["global_batch"], 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((p["global_batch"],), jnp.int32)
    iospecs = batch_pspecs({"tokens": tok, "pos": pos}, mesh, multi_pod)
    ioshard = shardings_for(iospecs, mesh)
    fn = jax.jit(serve_step,
                 in_shardings=(pshard, cshard, ioshard["tokens"],
                               ioshard["pos"]),
                 out_shardings=(None, cshard),
                 donate_argnums=(1,))
    return fn, (param_shapes, cache_shapes, tok, pos), {}


def analyse_compiled(compiled) -> Dict[str, Any]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    colls = collective_stats(txt)
    return {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            # jaxlib < 0.5 has no peak_memory_in_bytes; args+outputs+temps
            # minus aliased (donated) bytes bounds the live set — donated
            # params/opt buffers must not be counted as both arg and output
            "peak_bytes": int(getattr(
                ma, "peak_memory_in_bytes",
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)),
        },
        "collectives": colls,
        "collective_operand_bytes_per_device": total_collective_bytes(colls),
        "hlo_bytes": len(txt),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str, extrapolate: bool = True,
             act_shard: bool = False) -> Dict[str, Any]:
    import contextlib

    from repro.configs import get_config
    from repro.dist.act_sharding import activation_sharding
    from repro.launch.mesh import make_production_mesh

    multi_pod = mesh_kind == "multi"
    cfg = get_config(arch)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": 512 if multi_pod else 256,
        "seq_len": SHAPES[shape_name]["seq_len"],
        "global_batch": SHAPES[shape_name]["global_batch"],
        "kind": SHAPES[shape_name]["kind"],
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    reason = skip_reason(cfg, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        return _save(rec, out_dir)

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if multi_pod else "data"

    def ctx_factory():
        return (activation_sharding(dp, "model") if act_shard
                else contextlib.nullcontext())

    rec["act_shard"] = act_shard
    try:
        t0 = time.time()
        fn, args, meta = build_cell(cfg, shape_name, mesh, multi_pod)
        with mesh, ctx_factory():
            lowered = fn.lower(*args)
            rec["lower_seconds"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_seconds"] = round(time.time() - t1, 1)
        rec.update(meta)
        rec["full"] = analyse_compiled(compiled)
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")

        if extrapolate:
            with ctx_factory():
                rec["extrapolated"] = _depth_extrapolate(
                    cfg, shape_name, mesh, multi_pod)
        rec["status"] = "ok"
    except Exception as e:  # record the failure — these are bugs to fix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _save(rec, out_dir)


def _depth_extrapolate(cfg, shape_name, mesh, multi_pod) -> Dict[str, Any]:
    """Per-layer delta from two reduced-depth compiles, extrapolated to the
    full depth (corrects scan-body-counted-once in HloCostAnalysis)."""
    c1, c2, u1, u2, u_full = depth_variants(cfg)
    out = {}
    for label, c in (("d1", c1), ("d2", c2)):
        fn, args, _ = build_cell(c, shape_name, mesh, multi_pod)
        with mesh:
            compiled = fn.lower(*args).compile()
        a = analyse_compiled(compiled)
        out[label] = {
            "flops": a["flops_per_device"],
            "coll_bytes": a["collective_operand_bytes_per_device"],
            "bytes_accessed": a["bytes_accessed_per_device"],
        }
    du = u2 - u1
    scale = (u_full - u2) / du
    flops = out["d2"]["flops"] + (out["d2"]["flops"] - out["d1"]["flops"]) * scale
    coll = out["d2"]["coll_bytes"] + (
        out["d2"]["coll_bytes"] - out["d1"]["coll_bytes"]) * scale
    bytes_acc = out["d2"]["bytes_accessed"] + (
        out["d2"]["bytes_accessed"] - out["d1"]["bytes_accessed"]) * scale
    return {
        "probe": out, "units_full": u_full,
        "flops_per_device": flops,
        "collective_operand_bytes_per_device": coll,
        "bytes_accessed_per_device": bytes_acc,
    }


def _save(rec: Dict[str, Any], out_dir: str) -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(
        out_dir, f"{rec['mesh']}_{rec['arch']}_{rec['shape']}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        extra = (f" flops/dev={rec['full']['flops_per_device']:.3e}"
                 f" peak={rec['full']['memory']['peak_bytes']/2**30:.2f}GiB"
                 f" coll={rec['full']['collective_operand_bytes_per_device']/2**20:.1f}MiB"
                 f" ({rec.get('lower_seconds', 0)}s lower,"
                 f" {rec.get('compile_seconds', 0)}s compile)")
    elif status == "error":
        extra = " " + rec["error"][:160]
    print(f"[{status}] {rec['mesh']}/{rec['arch']}/{rec['shape']}{extra}",
          flush=True)
    return rec


def main() -> None:
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--act-shard", action="store_true",
                    help="explicit activation sharding constraints "
                         "(EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    n_ok = n_err = n_skip = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                fn = os.path.join(args.out, f"{mesh_kind}_{arch}_{shape}.json")
                if args.skip_existing and os.path.exists(fn):
                    with open(fn) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[cached] {mesh_kind}/{arch}/{shape}")
                            continue
                rec = run_cell(arch, shape, mesh_kind, args.out,
                               extrapolate=not args.no_extrapolate,
                               act_shard=args.act_shard)
                n_ok += rec["status"] == "ok"
                n_err += rec["status"] == "error"
                n_skip += rec["status"] == "skipped"
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
