"""Mixture-of-Experts layer: top-k routing with capacity-bounded sort-based
dispatch (GSPMD/EP-friendly: the (E, C, d) buffers shard the expert dim over
the 'model' mesh axis, turning dispatch/combine into all-to-alls) plus
always-on shared experts (DeepSeek-V3 / Kimi-K2 style).

Compute scales with E·C ≈ T·topk·capacity_factor — i.e. with *active*
experts only, matching the 6·N_active·D flop model used by the roofline.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.act_sharding import constrain
from .layers import dense_init, mlp, mlp_init

Params = Dict[str, Any]


def moe_init(key, cfg) -> Params:
    d, dff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    scale = (1.0 / d) ** 0.5
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, dff), jnp.float32)
                   * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, dff), jnp.float32)
                 * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, dff, d), jnp.float32)
                   * (1.0 / dff) ** 0.5).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d,
                               cfg.moe_d_ff * cfg.n_shared_experts, dt)
    return p


def _capacity(t: int, k: int, e: int, factor: float) -> int:
    c = int(t * k * factor / e) + 1
    c = max(4, min(c, t))
    if c > 256:
        c = -(-c // 256) * 256   # round up: TPU-tile friendly + shardable
    return c


def moe_apply(p: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) → (out (B,S,d), aux load-balance loss scalar)."""
    b, s, d = x.shape
    t = b * s
    k, e = cfg.experts_per_tok, cfg.n_experts
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                   # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalise

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    # --- sort-based capacity dispatch -----------------------------------
    c = _capacity(t, k, e, cfg.capacity_factor)
    flat_e = idx.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(flat_e, stable=True)                   # group by expert
    sorted_e = flat_e[order]
    # rank within expert group
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(t * k) - starts[sorted_e]
    keep = rank < c
    tok = order // k                                           # source token
    buf = jnp.zeros((e, c, d), x.dtype)
    buf = buf.at[sorted_e, jnp.where(keep, rank, 0)].add(
        jnp.where(keep[:, None], xt[tok], 0).astype(x.dtype))
    # NOTE (§Perf iterations 1–2): constraining the dispatch buffers made
    # collectives WORSE (E-only: 8x; E×C 2-D: still ~10x baseline) — the
    # scatter/gather pair re-partitions through whatever sharding we pin.
    # GSPMD's own choice for the dispatch path is better; leave it alone.

    # --- expert compute (E,C,d) @ (E,d,f) --------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])             # (E,C,d)

    # --- combine ------------------------------------------------------------
    gath = y[sorted_e, jnp.where(keep, rank, 0)]               # (T*k, d)
    gath = jnp.where(keep[:, None], gath, 0)
    gsort = gate_vals.reshape(-1)[order]
    out = jnp.zeros((t, d), jnp.float32).at[tok].add(
        gath.astype(jnp.float32) * gsort[:, None])
    out = out.astype(x.dtype)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xt)
    return out.reshape(b, s, d), aux


def moe_apply_dense_ref(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle: run every expert on every token, mask by top-k gates — the
    capacity-free semantics the dispatch must match (up to dropped tokens,
    so tests use capacity_factor high enough to drop nothing)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, cfg.experts_per_tok)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    gates = jnp.zeros((t, cfg.n_experts), jnp.float32)
    gates = jax.vmap(lambda g, i, v: g.at[i].set(v))(gates, idx, gate_vals)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"])) \
        * jnp.einsum("td,edf->tef", xt, p["w_up"])
    y = jnp.einsum("tef,efd->ted", h, p["w_down"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), gates)
    out = out.astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xt)
    return out.reshape(b, s, d)
