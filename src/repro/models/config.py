"""Model configuration dataclass covering all assigned architecture families
(dense / MoE / MLA / SSM / hybrid / enc-dec / VLM backbones)."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 → d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0              # per-expert hidden (d_ff used for dense ffn)
    first_dense_layers: int = 0    # leading dense layers before MoE layers
    capacity_factor: float = 1.25

    # --- MLA (DeepSeek) ------------------------------------------------------
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba) -----------------------------------------------------------
    ssm_version: int = 0           # 0 none, 1 mamba1, 2 mamba2/SSD
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_headdim: int = 64          # mamba2 head dim P
    dt_rank: int = 0               # mamba1; 0 → ceil(d_model/16)

    # --- hybrid (Zamba2) -----------------------------------------------------
    shared_attn_every: int = 0     # apply the shared attention block every k layers
    n_shared_blocks: int = 1       # distinct shared blocks cycled through

    # --- encoder-decoder (Whisper backbone) -----------------------------------
    enc_layers: int = 0
    enc_seq: int = 0               # encoder frames (stub frontend output length)

    # --- VLM backbone (InternVL) ---------------------------------------------
    n_vis_tokens: int = 0          # stub patch embeddings prepended to text

    # --- execution knobs -------------------------------------------------------
    attn_chunk: int = 0            # 0 → full attention; else online-softmax chunk
    remat: bool = True
    seq_shard_activations: bool = True
    scan_layers: bool = True       # False unrolls layer stacks (depth probes)

    # ------------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank or max(1, -(-self.d_model // 16))

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state: SSM and hybrid families."""
        return self.family in ("ssm", "hybrid")

    @property
    def moe_layer_ids(self) -> Tuple[int, ...]:
        if self.family != "moe":
            return ()
        return tuple(range(self.first_dense_layers, self.n_layers))

    def reduced(self, **over) -> "ModelConfig":
        """Smoke-test scale: same family/topology, tiny dims."""
        base = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab=512,
            head_dim=32 if self.head_dim else 0,
            dtype="float32",
        )
        if self.family == "moe":
            base.update(n_experts=min(self.n_experts, 8),
                        experts_per_tok=min(self.experts_per_tok, 2),
                        moe_d_ff=64,
                        first_dense_layers=min(self.first_dense_layers, 1))
        if self.mla:
            base.update(q_lora_rank=48, kv_lora_rank=32, qk_rope_dim=16,
                        qk_nope_dim=32, v_head_dim=32, head_dim=0)
        if self.ssm_version:
            base.update(ssm_state=min(self.ssm_state, 16), ssm_headdim=32,
                        dt_rank=8)
        if self.shared_attn_every:
            base.update(shared_attn_every=2, n_layers=4)
        if self.enc_layers:
            base.update(enc_layers=2, enc_seq=32)
        if self.n_vis_tokens:
            base.update(n_vis_tokens=16)
        base.update(over)
        return dataclasses.replace(self, **base)

    # --- analytic parameter / flop model (for roofline §Roofline) -----------
    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.mla:
            qk_hd = self.qk_nope_dim + self.qk_rope_dim
            per_attn = (d * self.q_lora_rank
                        + self.q_lora_rank * self.n_heads * qk_hd
                        + d * (self.kv_lora_rank + self.qk_rope_dim)
                        + self.kv_lora_rank * self.n_heads
                        * (self.qk_nope_dim + self.v_head_dim)
                        + self.n_heads * self.v_head_dim * d)
        per_dense_ffn = 3 * d * self.d_ff
        n = emb
        if self.family in ("dense", "vlm"):
            n += self.n_layers * (per_attn + per_dense_ffn)
        elif self.family == "moe":
            per_moe = (3 * d * self.moe_d_ff
                       * (self.n_experts + self.n_shared_experts)
                       + d * self.n_experts)
            n += self.first_dense_layers * (per_attn + per_dense_ffn)
            n += (self.n_layers - self.first_dense_layers) * (per_attn + per_moe)
        elif self.family == "ssm":
            di, N = self.d_inner, self.ssm_state
            per = (2 * d * di + di * self.d_conv
                   + di * (self.dtr + 2 * N) + self.dtr * di
                   + di * N + di + di * d)
            n += self.n_layers * per
        elif self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            H, P = self.n_ssm_heads, self.ssm_headdim
            per = (d * (2 * di + 2 * N + H) + di * self.d_conv
                   + 2 * H + di * d)
            n += self.n_layers * per
            d2 = 2 * d
            shared = (4 * d2 * d2 + 3 * d2 * d2)  # attn + ffn on concat width
            n += self.n_shared_blocks * shared
            n_sites = self.n_layers // max(self.shared_attn_every, 1)
            n += n_sites * (d2 * d)               # per-site down-projection
        elif self.family == "encdec":
            n += self.enc_layers * (per_attn + per_dense_ffn)
            n += self.n_layers * (2 * per_attn + per_dense_ffn)  # self+cross
        return int(n)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top-k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        per_moe_active = (3 * d * self.moe_d_ff
                          * (self.experts_per_tok + self.n_shared_experts)
                          + d * self.n_experts)
        per_moe_full = (3 * d * self.moe_d_ff
                        * (self.n_experts + self.n_shared_experts)
                        + d * self.n_experts)
        n_moe_layers = self.n_layers - self.first_dense_layers
        return int(self.param_count()
                   - n_moe_layers * (per_moe_full - per_moe_active))

    def model_flops(self, n_tokens: int, backward: bool = True) -> float:
        """6·N_active·D (2·N·D forward, 4·N·D backward)."""
        mult = 6.0 if backward else 2.0
        return mult * self.active_param_count() * n_tokens
