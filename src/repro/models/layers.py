"""Core transformer layers: RMSNorm, RoPE, GQA attention (full / chunked /
decode-with-cache), SwiGLU MLP.  Pure functions over explicit param pytrees;
init functions mirror each apply function.

Attention defaults to *chunked online-softmax* (lax.scan over KV blocks —
the same math as the flash_attention Pallas kernel) once the sequence
exceeds ``attn_chunk``, so 32 k-token prefills never materialise S² scores.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.act_sharding import constrain

Params = Dict[str, Any]


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


# --- RMSNorm ------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["scale"]).astype(x.dtype)


# --- rotary embeddings ----------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, hd), positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin,
    ], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, offset: int = 0) -> jnp.ndarray:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (jnp.log(10000.0) / d))[None, :]
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# --- GQA attention ------------------------------------------------------------

def attention_init(key, cfg, d_in: Optional[int] = None) -> Params:
    d = d_in or cfg.d_model
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _qkv(p: Params, cfg, x: jnp.ndarray, positions, d_in=None):
    b, s, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B,S,Hkv,hd) → (B,S,H,hd) by repeating each kv head."""
    hkv = k.shape[2]
    if hkv == n_heads:
        return k
    rep = n_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def sdpa_full(q, k, v, causal: bool = True,
              q_offset: int = 0) -> jnp.ndarray:
    """q: (B,Sq,H,hd); k,v: (B,Sk,H,hd).  fp32 softmax."""
    hd = q.shape[-1]
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qi = jnp.arange(sq)[:, None] + q_offset
        kj = jnp.arange(sk)[None, :]
        scores = jnp.where(qi >= kj, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def sdpa_chunked(q, k, v, chunk: int, causal: bool = True) -> jnp.ndarray:
    """Online-softmax over KV chunks (flash-attention math, pure jnp).
    Requires Sk % chunk == 0.  Same-length causal self-attention."""
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    b, sq, h, hd = q.shape
    vd = v.shape[-1]
    sk = k.shape[1]
    assert sk % chunk == 0, (sk, chunk)
    nk = sk // chunk
    scale = hd ** -0.5
    kc = k.reshape(b, nk, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, chunk, h, vd).transpose(1, 0, 2, 3, 4)
    qi = jnp.arange(sq)[:, None]

    def body(carry, inp):
        m, l, acc = carry            # (B,H,Sq), (B,H,Sq), (B,Sq,H,hd) fp32
        kb, vb, kidx = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                       preferred_element_type=jnp.float32) * scale
        s = constrain(s, "dp", "tp", None, None)
        if causal:
            kj = kidx * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where(qi >= kj, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) → nan
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(m), corr, 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr.transpose(0, 2, 1)[..., None] \
            + jnp.einsum("bhqk,bkhd->bqhd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = constrain(jnp.full((b, h, sq), -jnp.inf, jnp.float32),
                   "dp", "tp", None)
    l0 = constrain(jnp.zeros((b, h, sq), jnp.float32), "dp", "tp", None)
    acc0 = constrain(jnp.zeros((b, sq, h, vd), jnp.float32),
                     "dp", None, "tp", None)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kc, vc, jnp.arange(nk)))
    l = jnp.maximum(l, 1e-30)
    return (acc / l.transpose(0, 2, 1)[..., None]).astype(q.dtype)


def attention(p: Params, cfg, x: jnp.ndarray, positions,
              return_kv: bool = False):
    """Causal self-attention over (B,S,d).  ``return_kv`` also returns the
    pre-repeat (B,S,Hkv,hd) keys/values for prefill cache construction."""
    b, s, d = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    kf = _repeat_kv(k, cfg.n_heads)
    vf = _repeat_kv(v, cfg.n_heads)
    if cfg.attn_chunk and s > cfg.attn_chunk and s % cfg.attn_chunk == 0:
        o = sdpa_chunked(q, kf, vf, cfg.attn_chunk)
    else:
        o = sdpa_full(q, kf, vf)
    out = o.reshape(b, s, -1) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(p: Params, cfg, x: jnp.ndarray, cache: Tuple,
                     pos: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple]:
    """One-token decode: x (B,1,d), cache = (k,v) each (B,Smax,Hkv,hd),
    pos (B,) current index.  Returns (out (B,1,d), new cache)."""
    b = x.shape[0]
    ck, cv = cache
    q, k, v = _qkv(p, cfg, x, pos[:, None])
    ck = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice(
        c, upd, (i, 0, 0)))(ck, k, pos)
    cv = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice(
        c, upd, (i, 0, 0)))(cv, v, pos)
    kf = _repeat_kv(ck, cfg.n_heads)
    vf = _repeat_kv(cv, cfg.n_heads)
    hd = cfg.hd
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf,
                        preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(ck.shape[1])[None, :] <= pos[:, None])
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    return o.reshape(b, 1, -1) @ p["wo"], (ck, cv)


def cross_attention(p: Params, cfg, x: jnp.ndarray,
                    kv_src: jnp.ndarray) -> jnp.ndarray:
    """Encoder-decoder cross attention (no RoPE, no mask)."""
    b, s, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (kv_src @ p["wk"]).reshape(b, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = (kv_src @ p["wv"]).reshape(b, kv_src.shape[1], cfg.n_kv_heads, hd)
    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    o = sdpa_full(q, k, v, causal=False)
    return o.reshape(b, s, -1) @ p["wo"]


# --- SwiGLU MLP ------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, d_ff, dtype),
        "w_up": dense_init(ks[1], d, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d, dtype),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
