"""LM model zoo: the assigned architectures as pure-JAX functional modules."""
