"""lax.scan over layer stacks, or a Python unroll when
``cfg.scan_layers=False``.

The unrolled form exists for the dry-run's depth probes: XLA's
HloCostAnalysis counts a while-loop body once regardless of trip count, so
per-layer flop/collective deltas must come from unrolled reduced-depth
lowers (launch/dryrun.py::_depth_extrapolate)."""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def scan_layers(cfg, f: Callable, init, xs):
    """Semantics of ``jax.lax.scan(f, init, xs)`` (xs stacked on axis 0)."""
    if getattr(cfg, "scan_layers", True):
        return jax.lax.scan(f, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if not ys or ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked
