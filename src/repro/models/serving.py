"""Serving paths: cache init, prefill and single-token decode for every
architecture family.  Caches are layer-stacked pytrees consumed by
``lax.scan`` (one traced decode layer regardless of depth).

Cache shapes per family (L = layers, B = batch, S = max_seq):
  dense/moe/vlm : k,v            (L, B, S, Hkv, hd)
  mla (deepseek): c_kv (L,B,S,lat), k_rope (L,B,S,rope)   — compressed!
  ssm (mamba1)  : conv (L,B,K-1,dI), h (L,B,dI,N)          — O(1) in S
  hybrid        : trunk conv/h (as ssm) + per-site shared-attn k,v
  encdec        : decoder self k,v + precomputed cross k,v (enc_seq)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (_repeat_kv, attention, attention_decode, attention_init,
                     cross_attention, mlp, rmsnorm, sdpa_full, sinusoidal_pos)
from .lm import _dense_block, _moe_block, _shared_cfg, logits_fn
from .scan_util import scan_layers as _scan_or_unroll

Params = Dict[str, Any]


# =============================================================================
# cache init
# =============================================================================

def init_cache(cfg, batch: int, max_seq: int) -> Params:
    dt = jnp.dtype(cfg.dtype)
    fam = cfg.family
    L = cfg.n_layers

    def kv(layers, heads, hd, seq):
        return {"k": jnp.zeros((layers, batch, seq, heads, hd), dt),
                "v": jnp.zeros((layers, batch, seq, heads, hd), dt)}

    if fam in ("dense", "vlm", "moe"):
        if cfg.mla:
            return {"c_kv": jnp.zeros((L, batch, max_seq, cfg.kv_lora_rank), dt),
                    "k_rope": jnp.zeros((L, batch, max_seq, cfg.qk_rope_dim), dt)}
        return kv(L, cfg.n_kv_heads, cfg.hd, max_seq)
    if fam == "ssm":
        return {"conv": jnp.zeros((L, batch, cfg.d_conv - 1, cfg.d_inner),
                                  jnp.float32),
                "h": jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state),
                               jnp.float32)}
    if fam == "hybrid":
        n_sites = cfg.n_layers // cfg.shared_attn_every
        scfg = _shared_cfg(cfg)
        k = cfg.d_conv - 1
        return {
            "conv_x": jnp.zeros((L, batch, k, cfg.d_inner), jnp.float32),
            "conv_b": jnp.zeros((L, batch, k, cfg.ssm_state), jnp.float32),
            "conv_c": jnp.zeros((L, batch, k, cfg.ssm_state), jnp.float32),
            "h": jnp.zeros((L, batch, cfg.n_ssm_heads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32),
            "shared": kv(n_sites, scfg.n_kv_heads, scfg.hd, max_seq),
        }
    if fam == "encdec":
        return {
            "self": kv(L, cfg.n_kv_heads, cfg.hd, max_seq),
            "cross": kv(L, cfg.n_kv_heads, cfg.hd, cfg.enc_seq),
        }
    raise ValueError(fam)


# =============================================================================
# prefill — forward over the prompt, emitting the cache
# =============================================================================

def _prefill_attn(lp, cfg, h, positions):
    """The attention/KV half every prefill block shares (dense, leading-
    dense MoE and MoE blocks are identical up to the FFN): pre-norm
    attention — MLA latent or standard KV — with residual add.  Returns
    ``(h + attn, kv_cache_leaf)``; the cache leaf layout matches
    ``init_cache`` for the family (guarded token-for-token by
    ``tests/test_serve.py``)."""
    hn = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
    if cfg.mla:
        a, lat = mla_mod.mla_attention(lp["attn"], cfg, hn, positions,
                                       return_latent=True)
        kv = {"c_kv": lat[0], "k_rope": lat[1]}
    else:
        a, (k, v) = attention(lp["attn"], cfg, hn, positions,
                              return_kv=True)
        kv = {"k": k, "v": v}
    return h + a, kv


def prefill(params: Params, cfg, tokens: jnp.ndarray,
            extra: Optional[Dict[str, jnp.ndarray]] = None):
    """tokens (B,S) → (last-token logits (B,V), cache, next_pos (B,))."""
    extra = extra or {}
    b, s = tokens.shape
    x = params["embed"]["tok"][tokens]
    fam = cfg.family
    if fam == "vlm":
        x = jnp.concatenate([extra["vis_embeds"].astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def scan_emit(block_fn, stack, h):
        def body(hh, lp):
            hh, cache_l = block_fn(lp, hh)
            return hh, cache_l
        return _scan_or_unroll(cfg, body, h, stack)

    def blk_dense(lp, h):
        h, kv = _prefill_attn(lp, cfg, h, positions)
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps))
        return h, kv

    def blk_moe(lp, h):
        h, kv = _prefill_attn(lp, cfg, h, positions)
        y, _ = moe_mod.moe_apply(lp["moe"], cfg,
                                 rmsnorm(lp["mlp_norm"], h, cfg.norm_eps))
        return h + y, kv

    cache: Params
    if fam in ("dense", "vlm"):
        x, cache = scan_emit(blk_dense, params["layers"], x)
    elif fam == "moe":
        caches = []
        if cfg.first_dense_layers:
            x, c0 = scan_emit(blk_dense, params["dense_layers"], x)
            caches.append(c0)
        x, c1 = scan_emit(blk_moe, params["moe_layers"], x)
        caches.append(c1)
        cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *caches) \
            if len(caches) > 1 else caches[0]
    elif fam == "ssm":
        def blk(lp, h):
            y, st = ssm_mod.mamba1_apply(
                lp["mamba"], cfg, rmsnorm(lp["norm"], h, cfg.norm_eps),
                return_state=True)
            return h + y, st
        x, cache = scan_emit(blk, params["layers"], x)
    elif fam == "hybrid":
        x, cache = _hybrid_prefill(params, cfg, x, positions)
    elif fam == "encdec":
        x, cache = _encdec_prefill(params, cfg, x, positions, extra)
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x[:, -1])
    next_pos = jnp.full((b,), x.shape[1], jnp.int32)
    return logits, cache, next_pos


def _hybrid_prefill(params, cfg, x, positions):
    every = cfg.shared_attn_every
    n_sites = cfg.n_layers // every
    n_body = n_sites * every
    emb0 = x
    scfg = _shared_cfg(cfg)

    seg_stack = jax.tree.map(
        lambda a: a[:n_body].reshape((n_sites, every) + a.shape[1:]),
        params["layers"])
    tail_stack = jax.tree.map(lambda a: a[n_body:], params["layers"])

    def mamba_blk(lp, h):
        y, st = ssm_mod.mamba2_apply(
            lp["mamba"], cfg, rmsnorm(lp["norm"], h, cfg.norm_eps),
            return_state=True)
        return h + y, st

    def segment(h, seg):
        seg_layers, site_proj, site_idx = seg
        h, trunk_cache = _scan_or_unroll(
            cfg, lambda hh, lp: mamba_blk(lp, hh), h, seg_layers)
        block_idx = site_idx % cfg.n_shared_blocks
        sp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, block_idx, 0,
                                                   keepdims=False),
            params["shared"])
        cat = jnp.concatenate([h, emb0], axis=-1)
        a, (k, v) = attention(sp["attn"], scfg,
                              rmsnorm(sp["norm"], cat, cfg.norm_eps),
                              positions, return_kv=True)
        u = cat + a
        u = u + mlp(sp["mlp"], rmsnorm(sp["mlp_norm"], u, cfg.norm_eps))
        h = h + u @ site_proj
        return h, (trunk_cache, {"k": k, "v": v})

    x, (seg_caches, shared_cache) = _scan_or_unroll(
        cfg, segment, x,
        (seg_stack, params["site_proj"], jnp.arange(n_sites)))
    if n_body < cfg.n_layers:
        x, tail_cache = _scan_or_unroll(
            cfg, lambda hh, lp: (mamba_blk(lp, hh)), x, tail_stack)
        trunk = jax.tree.map(
            lambda a, t: jnp.concatenate(
                [a.reshape((n_body,) + a.shape[2:]), t], axis=0),
            seg_caches, tail_cache)
    else:
        trunk = jax.tree.map(
            lambda a: a.reshape((n_body,) + a.shape[2:]), seg_caches)
    trunk["shared"] = shared_cache
    return x, trunk


def _encdec_prefill(params, cfg, x, positions, extra):
    frames = extra["frames"].astype(x.dtype)
    e = frames + sinusoidal_pos(frames.shape[1], cfg.d_model).astype(x.dtype)
    ecfg = dataclasses.replace(cfg, attn_chunk=0)

    def enc_block(h, lp):
        h = h + attention(lp["attn"], ecfg,
                          rmsnorm(lp["attn_norm"], h, cfg.norm_eps),
                          jnp.broadcast_to(jnp.arange(h.shape[1]),
                                           h.shape[:2]))
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps))
        return h, None

    e, _ = _scan_or_unroll(cfg, enc_block, e, params["enc_layers"])
    e = rmsnorm(params["enc_norm"], e, cfg.norm_eps)
    x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)

    def dec_block(h, lp):
        hn = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, (k, v) = attention(lp["attn"], cfg, hn, positions, return_kv=True)
        h = h + a
        # precompute this layer's cross K/V from the encoder output
        b_, f_ = e.shape[0], e.shape[1]
        ck = (e @ lp["cross"]["wk"]).reshape(b_, f_, cfg.n_kv_heads, cfg.hd)
        cv = (e @ lp["cross"]["wv"]).reshape(b_, f_, cfg.n_kv_heads, cfg.hd)
        h = h + cross_attention(lp["cross"], cfg,
                                rmsnorm(lp["cross_norm"], h, cfg.norm_eps), e)
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps))
        return h, {"self": {"k": k, "v": v}, "cross": {"k": ck, "v": cv}}

    x, caches = _scan_or_unroll(cfg, dec_block, x, params["dec_layers"])
    return x, {"self": caches["self"], "cross": caches["cross"]}


# =============================================================================
# decode — one token against the cache
# =============================================================================

def decode_step(params: Params, cfg, cache: Params, tokens: jnp.ndarray,
                pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """tokens (B,1), pos (B,) → (logits (B,V), new cache).  Cache buffers
    are donated by the jitted serve_step wrapper."""
    fam = cfg.family
    x = params["embed"]["tok"][tokens]
    if fam in ("dense", "vlm", "moe"):
        x, cache = _decode_attn_stack(params, cfg, cache, x, pos)
    elif fam == "ssm":
        def body(h, inp):
            lp, cl = inp
            y, cl2 = ssm_mod.mamba1_decode(
                lp["mamba"], cfg, rmsnorm(lp["norm"], h, cfg.norm_eps), cl)
            return h + y, cl2
        x, cache = _scan_or_unroll(cfg, body, x, (params["layers"], cache))
    elif fam == "hybrid":
        x, cache = _decode_hybrid(params, cfg, cache, x, pos)
    elif fam == "encdec":
        x, cache = _decode_encdec(params, cfg, cache, x, pos)
    else:
        raise ValueError(fam)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x[:, 0]), cache


def _decode_attn_stack(params, cfg, cache, x, pos):
    stacks = []
    if cfg.family == "moe" and cfg.first_dense_layers:
        nd = cfg.first_dense_layers
        cache_d = jax.tree.map(lambda a: a[:nd], cache)
        cache_m = jax.tree.map(lambda a: a[nd:], cache)

        def body_d(h, inp):
            lp, cl = inp
            h, cl2 = _decode_block(lp, cfg, h, cl, pos, moe=False)
            return h, cl2

        def body_m(h, inp):
            lp, cl = inp
            h, cl2 = _decode_block(lp, cfg, h, cl, pos, moe=True)
            return h, cl2

        x, c0 = _scan_or_unroll(cfg, body_d, x,
                                (params["dense_layers"], cache_d))
        x, c1 = _scan_or_unroll(cfg, body_m, x,
                                (params["moe_layers"], cache_m))
        cache = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                             c0, c1)
        return x, cache

    stack = params["layers"] if cfg.family != "moe" else params["moe_layers"]
    is_moe = cfg.family == "moe"

    def body(h, inp):
        lp, cl = inp
        h, cl2 = _decode_block(lp, cfg, h, cl, pos, moe=is_moe)
        return h, cl2

    return _scan_or_unroll(cfg, body, x, (stack, cache))


def decode_step_paged(params: Params, cfg, leaves: Params,
                      page_rows: jnp.ndarray, tokens: jnp.ndarray,
                      pos: jnp.ndarray, *, page_size: int,
                      interpret: Optional[bool] = None
                      ) -> Tuple[jnp.ndarray, Params]:
    """One decode step straight against the block pool (attention
    families only): tokens (B,1), page_rows (B, max_pages), pos (B,) →
    (logits (B,V), updated pool leaves).  Per layer, the paged-attention
    kernel (``kernels/paged_attention``) walks each slot's page table
    in-kernel — no contiguous-cache gather, no scatter; the new token's
    K/V lands in its ``(page, offset)`` cell through aliased refs.  The
    non-cache halves (projections, MoE/MLP, logits) are identical to
    :func:`decode_step`."""
    fam = cfg.family
    if fam not in ("dense", "vlm", "moe"):
        raise ValueError(f"decode_step_paged supports attention families, "
                         f"not {fam!r}")
    x = params["embed"]["tok"][tokens]

    def body(moe):
        def step(h, inp):
            lp, leaf_l = inp
            h, leaf_l2 = _paged_decode_block(
                lp, cfg, h, leaf_l, page_rows, pos, moe=moe,
                page_size=page_size, interpret=interpret)
            return h, leaf_l2
        return step

    if fam == "moe" and cfg.first_dense_layers:
        nd = cfg.first_dense_layers
        leaves_d = jax.tree.map(lambda a: a[:nd], leaves)
        leaves_m = jax.tree.map(lambda a: a[nd:], leaves)
        x, l0 = _scan_or_unroll(cfg, body(False), x,
                                (params["dense_layers"], leaves_d))
        x, l1 = _scan_or_unroll(cfg, body(True), x,
                                (params["moe_layers"], leaves_m))
        leaves = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                              l0, l1)
    else:
        stack = (params["layers"] if fam != "moe"
                 else params["moe_layers"])
        x, leaves = _scan_or_unroll(cfg, body(fam == "moe"), x,
                                    (stack, leaves))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x[:, 0]), leaves


def _paged_decode_block(lp, cfg, h, leaf, page_rows, pos, *, moe: bool,
                        page_size: int, interpret: Optional[bool]):
    """One decoder layer against its per-layer pool slice ``leaf`` —
    the paged twin of :func:`_decode_block`."""
    from repro.kernels import paged_attention as paged_ops
    from .layers import _qkv
    b = h.shape[0]
    hn = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
    p = lp["attn"]
    if cfg.mla:
        nope, vd, rd = cfg.qk_nope_dim, cfg.v_head_dim, cfg.qk_rope_dim
        lat = cfg.kv_lora_rank
        nh = cfg.n_heads
        q_nope, q_rope = mla_mod._mla_q(p, cfg, hn, pos[:, None])
        c_new, r_new = mla_mod._mla_kv_latent(p, cfg, hn, pos[:, None])
        w_uk = p["wkv_b"].reshape(lat, nh, nope + vd)[..., :nope]
        w_uv = p["wkv_b"].reshape(lat, nh, nope + vd)[..., nope:]
        q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)
        ctx, c_pool, r_pool = paged_ops.paged_mla_decode(
            q_eff[:, 0], q_rope[:, 0], c_new[:, 0], r_new[:, 0],
            leaf["c_kv"], leaf["k_rope"], page_rows, pos,
            page_size=page_size, scale=(nope + rd) ** -0.5,
            interpret=interpret)
        o = jnp.einsum("bhl,lhv->bhv", ctx.astype(h.dtype), w_uv)
        a = o.reshape(b, 1, -1) @ p["wo"]
        leaf2 = {"c_kv": c_pool, "k_rope": r_pool}
    else:
        q, k, v = _qkv(p, cfg, hn, pos[:, None])
        o, k_pool, v_pool = paged_ops.paged_gqa_decode(
            q[:, 0], k[:, 0], v[:, 0], leaf["k"], leaf["v"],
            page_rows, pos, page_size=page_size, interpret=interpret)
        a = o.astype(h.dtype).reshape(b, 1, -1) @ p["wo"]
        leaf2 = {"k": k_pool, "v": v_pool}
    h = h + a
    hn = rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
    if moe:
        y, _ = moe_mod.moe_apply(lp["moe"], cfg, hn)
    else:
        y = mlp(lp["mlp"], hn)
    return h + y, leaf2


def sample_tokens(logits: jnp.ndarray, keys: jnp.ndarray,
                  temperature: float, top_k: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Next-token selection for the serving tier: greedy argmax when
    ``temperature == 0`` (the conformance oracle — keys pass through
    untouched), otherwise temperature + optional top-k sampling with one
    PRNG key per row.  ``keys`` is a ``(B, 2)`` uint32 stack of raw
    threefry keys; each sampled row consumes a split, so repeated calls
    under a fixed seed are deterministic.  Returns ``(tokens (B,) int32,
    new keys)``."""
    if temperature == 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32), keys
    scaled = logits.astype(jnp.float32) / temperature
    if top_k and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(scaled, top_k)[0][:, -1]
        scaled = jnp.where(scaled >= kth[:, None], scaled, -jnp.inf)
    split = jax.vmap(jax.random.split)(keys)           # (B, 2, 2)
    nxt = jax.vmap(jax.random.categorical)(split[:, 0], scaled)
    return nxt.astype(jnp.int32), split[:, 1]


def _decode_block(lp, cfg, h, cl, pos, moe: bool):
    hn = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
    if cfg.mla:
        a, cl2 = mla_mod.mla_decode(lp["attn"], cfg, hn, cl, pos)
    else:
        a, (ck, cv) = attention_decode(lp["attn"], cfg, hn,
                                       (cl["k"], cl["v"]), pos)
        cl2 = {"k": ck, "v": cv}
    h = h + a
    hn = rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
    if moe:
        y, _ = moe_mod.moe_apply(lp["moe"], cfg, hn)
    else:
        y = mlp(lp["mlp"], hn)
    return h + y, cl2


def _decode_hybrid(params, cfg, cache, x, pos):
    every = cfg.shared_attn_every
    n_sites = cfg.n_layers // every
    n_body = n_sites * every
    emb0 = x
    scfg = _shared_cfg(cfg)

    trunk_cache = {k_: cache[k_]
                   for k_ in ("conv_x", "conv_b", "conv_c", "h")}
    seg_cache = jax.tree.map(
        lambda a: a[:n_body].reshape((n_sites, every) + a.shape[1:]),
        trunk_cache)
    tail_cache = jax.tree.map(lambda a: a[n_body:], trunk_cache)
    seg_stack = jax.tree.map(
        lambda a: a[:n_body].reshape((n_sites, every) + a.shape[1:]),
        params["layers"])
    tail_stack = jax.tree.map(lambda a: a[n_body:], params["layers"])

    def mamba_step(h, inp):
        lp, cl = inp
        y, cl2 = ssm_mod.mamba2_decode(
            lp["mamba"], cfg, rmsnorm(lp["norm"], h, cfg.norm_eps), cl)
        return h + y, cl2

    def segment(h, inp):
        seg_layers, cl_seg, shared_kv, site_proj, site_idx = inp
        h, cl_seg2 = _scan_or_unroll(cfg, mamba_step, h,
                                     (seg_layers, cl_seg))
        block_idx = site_idx % cfg.n_shared_blocks
        sp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, block_idx, 0,
                                                   keepdims=False),
            params["shared"])
        cat = jnp.concatenate([h, emb0], axis=-1)
        a, (ck, cv) = attention_decode(
            sp["attn"], scfg, rmsnorm(sp["norm"], cat, cfg.norm_eps),
            (shared_kv["k"], shared_kv["v"]), pos)
        u = cat + a
        u = u + mlp(sp["mlp"], rmsnorm(sp["mlp_norm"], u, cfg.norm_eps))
        h = h + u @ site_proj
        return h, (cl_seg2, {"k": ck, "v": cv})

    x, (seg_cache2, shared2) = _scan_or_unroll(
        cfg, segment, x, (seg_stack, seg_cache, cache["shared"],
                          params["site_proj"], jnp.arange(n_sites)))
    if n_body < cfg.n_layers:
        x, tail2 = _scan_or_unroll(cfg, mamba_step, x,
                                   (tail_stack, tail_cache))
        trunk2 = jax.tree.map(
            lambda a, t: jnp.concatenate(
                [a.reshape((n_body,) + a.shape[2:]), t], axis=0),
            seg_cache2, tail2)
    else:
        trunk2 = jax.tree.map(
            lambda a: a.reshape((n_body,) + a.shape[2:]), seg_cache2)
    trunk2["shared"] = shared2
    return x, trunk2


def _sin_pos_at(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal embedding at per-batch positions: (B,) → (B,1,d)."""
    div = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (jnp.log(10000.0) / d))
    ang = pos[:, None].astype(jnp.float32) * div[None]
    pe = jnp.zeros((pos.shape[0], d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe[:, None]


def _decode_encdec(params, cfg, cache, x, pos):
    x = x + _sin_pos_at(pos, cfg.d_model).astype(x.dtype)

    def body(h, inp):
        lp, cl_self, cl_cross = inp
        hn = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, (ck, cv) = attention_decode(lp["attn"], cfg, hn,
                                       (cl_self["k"], cl_self["v"]), pos)
        h = h + a
        # cross attention against the precomputed encoder K/V
        hn = rmsnorm(lp["cross_norm"], h, cfg.norm_eps)
        b_ = h.shape[0]
        q = (hn @ lp["cross"]["wq"]).reshape(b_, 1, cfg.n_heads, cfg.hd)
        k = _repeat_kv(cl_cross["k"], cfg.n_heads)
        v = _repeat_kv(cl_cross["v"], cfg.n_heads)
        o = sdpa_full(q, k, v, causal=False)
        h = h + o.reshape(b_, 1, -1) @ lp["cross"]["wo"]
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps))
        return h, {"k": ck, "v": cv}

    x, self2 = _scan_or_unroll(cfg, body, x,
                               (params["dec_layers"], cache["self"],
                                cache["cross"]))
    return x, {"self": self2, "cross": cache["cross"]}
