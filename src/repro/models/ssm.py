"""State-space blocks: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2 trunk).

Scan strategies:
  * ``*_scan_ref``   — per-timestep ``lax.scan`` (the oracle; O(S) steps).
  * Mamba1 chunked   — ``associative_scan`` inside fixed-size chunks with a
    sequential carry across chunks (bounds the (B,Q,dI,N) working set).
  * Mamba2 SSD       — the matmul ("attention-like") chunk form: intra-chunk
    via (Q×Q) decay-masked score matmuls, inter-chunk via a carried state.
    This is the TPU-native formulation (MXU matmuls instead of elementwise
    recurrences).

Both carry exact single-step ``*_decode`` updates for serving (O(1) state:
the sub-quadratic long_500k story).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.act_sharding import constrain
from .layers import dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, Any]

SSM_CHUNK = 128


# --- causal depthwise conv (K taps) -------------------------------------------

def conv1d_causal(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,C), w: (C,K), b: (C,).  y_t = sum_k w[:,k] x_{t-K+1+k}."""
    k = w.shape[1]
    out = x * w[None, None, :, -1]
    for i in range(k - 1):
        shift = k - 1 - i
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xs * w[None, None, :, i]
    return out + b[None, None, :]


def conv1d_step(window: jnp.ndarray, xt: jnp.ndarray, w: jnp.ndarray,
                b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """window: (B,K-1,C) past inputs; xt: (B,C) new input.
    Returns (y (B,C), new window)."""
    full = jnp.concatenate([window, xt[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", full, w) + b[None, :]
    return y, full[:, 1:]


# --- linear recurrence h_t = a_t h_{t-1} + b_t ----------------------------------

def _assoc_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, b1 * a2 + b2


def linear_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                    h0: jnp.ndarray) -> jnp.ndarray:
    """Oracle: a,b (B,S,...), h0 (B,...) → h (B,S,...) via stepwise scan."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    aT = jnp.moveaxis(a, 1, 0)
    bT = jnp.moveaxis(b, 1, 0)
    _, hs = jax.lax.scan(step, h0, (aT, bT))
    return jnp.moveaxis(hs, 0, 1)


def linear_scan_chunked(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                        chunk: int = SSM_CHUNK) -> jnp.ndarray:
    """Chunked associative scan; exact (same recurrence, fp32)."""
    bsz, s = a.shape[:2]
    if s % chunk != 0:
        return linear_scan_ref(a, b, h0)
    nc = s // chunk
    ar = a.reshape((bsz, nc, chunk) + a.shape[2:])
    br = b.reshape((bsz, nc, chunk) + b.shape[2:])

    def outer(h, inp):
        ac, bc = inp                                # (B, Q, ...)
        pa, pb = jax.lax.associative_scan(_assoc_combine, (ac, bc), axis=1)
        hs = pb + pa * h[:, None]
        return hs[:, -1], hs

    _, hs = jax.lax.scan(outer, h0, (jnp.moveaxis(ar, 1, 0),
                                     jnp.moveaxis(br, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1)                     # (B, nc, Q, ...)
    return hs.reshape((bsz, s) + a.shape[2:])


# =============================================================================
# Mamba1
# =============================================================================

def mamba1_init(key, cfg) -> Params:
    d, di, n, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dtr
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (di, cfg.d_conv), jnp.float32)
                   * 0.2).astype(jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], di, dtr + 2 * n, dt),
        "dt_proj": dense_init(ks[3], dtr, di, jnp.float32),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus ≈ 0.01
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dt),
    }


def _mamba1_scan_inputs(p: Params, cfg, x: jnp.ndarray):
    """Shared front end: returns (a, b, c_t, z, xin) for the recurrence."""
    di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dtr
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                 # (B,S,dI) each
    xin = jax.nn.silu(conv1d_causal(xin.astype(jnp.float32), p["conv_w"],
                                    p["conv_b"])).astype(x.dtype)
    proj = xin @ p["x_proj"]                           # (B,S,dtr+2N)
    dt_raw = proj[..., :dtr]
    b_in = proj[..., dtr:dtr + n].astype(jnp.float32)
    c_in = proj[..., dtr + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) @ p["dt_proj"]
                         + p["dt_bias"])               # (B,S,dI)
    a_mat = -jnp.exp(p["a_log"])                       # (dI,N)
    a = jnp.exp(dt[..., None] * a_mat[None, None])     # (B,S,dI,N)
    b = (dt * xin.astype(jnp.float32))[..., None] * b_in[..., None, :]
    return a, b, c_in, z, xin


def mamba1_apply(p: Params, cfg, x: jnp.ndarray, chunked: bool = True,
                 return_state: bool = False):
    bsz, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    # pre-conv input needed for the decode conv window
    xz = x @ p["in_proj"]
    xin_raw = jnp.split(xz, 2, axis=-1)[0]
    a, b, c_in, z, xin = _mamba1_scan_inputs(p, cfg, x)
    a = constrain(a, "dp", None, "tp", None)
    b = constrain(b, "dp", None, "tp", None)
    h0 = constrain(jnp.zeros((bsz, di, n), jnp.float32), "dp", "tp", None)
    scan = linear_scan_chunked if chunked else linear_scan_ref
    h = scan(a, b, h0)                                 # (B,S,dI,N)
    y = jnp.einsum("bsdn,bsn->bsd", h, c_in) \
        + p["d_skip"][None, None] * xin.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    if return_state:
        k = cfg.d_conv - 1
        window = xin_raw[:, -k:].astype(jnp.float32)   # (B,K-1,dI)
        return out, {"conv": window, "h": h[:, -1]}
    return out


def mamba1_init_cache(cfg, batch: int):
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), jnp.float32),
        "h": jnp.zeros((batch, di, n), jnp.float32),
    }


def mamba1_decode(p: Params, cfg, x: jnp.ndarray, cache: Params):
    """x: (B,1,d) → (out (B,1,d), new cache).  Exact one-step recurrence."""
    bsz = x.shape[0]
    di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dtr
    xz = x[:, 0] @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                 # (B,dI)
    xc, conv = conv1d_step(cache["conv"], xin.astype(jnp.float32),
                           p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    proj = xc.astype(x.dtype) @ p["x_proj"]
    dt_raw = proj[..., :dtr]
    b_in = proj[..., dtr:dtr + n].astype(jnp.float32)
    c_in = proj[..., dtr + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) @ p["dt_proj"]
                         + p["dt_bias"])               # (B,dI)
    a_mat = -jnp.exp(p["a_log"])
    a = jnp.exp(dt[..., None] * a_mat[None])           # (B,dI,N)
    b = (dt * xc)[..., None] * b_in[:, None, :]
    h = a * cache["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, c_in) + p["d_skip"][None] * xc
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return (y @ p["out_proj"])[:, None], {"conv": conv, "h": h}


# =============================================================================
# Mamba2 (SSD)
# =============================================================================

def mamba2_init(key, cfg) -> Params:
    """Projections for z / x / B / C / dt are SEPARATE weights (not one
    concatenated in_proj) so each shards cleanly over the TP axis; the
    depthwise conv splits exactly across the channel groups (DESIGN.md §5)."""
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h = cfg.n_ssm_heads
    ks = jax.random.split(key, 9)
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_z": dense_init(ks[0], d, di, dt),
        "in_x": dense_init(ks[1], d, di, dt),
        "in_b": dense_init(ks[2], d, n, dt),
        "in_c": dense_init(ks[3], d, n, dt),
        "in_dt": dense_init(ks[4], d, h, dt),
        "conv_w_x": jax.random.normal(ks[5], (di, cfg.d_conv),
                                      jnp.float32) * 0.2,
        "conv_b_x": jnp.zeros((di,), jnp.float32),
        "conv_w_b": jax.random.normal(ks[6], (n, cfg.d_conv),
                                      jnp.float32) * 0.2,
        "conv_b_b": jnp.zeros((n,), jnp.float32),
        "conv_w_c": jax.random.normal(ks[7], (n, cfg.d_conv),
                                      jnp.float32) * 0.2,
        "conv_b_c": jnp.zeros((n,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(0) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(di),
        "out_proj": dense_init(ks[8], di, d, dt),
    }


def _mamba2_front(p: Params, cfg, x: jnp.ndarray):
    z = x @ p["in_z"]
    dt_raw = x @ p["in_dt"]                             # (B,S,H)
    xin = jax.nn.silu(conv1d_causal((x @ p["in_x"]).astype(jnp.float32),
                                    p["conv_w_x"], p["conv_b_x"]))
    b_in = jax.nn.silu(conv1d_causal((x @ p["in_b"]).astype(jnp.float32),
                                     p["conv_w_b"], p["conv_b_b"]))
    c_in = jax.nn.silu(conv1d_causal((x @ p["in_c"]).astype(jnp.float32),
                                     p["conv_w_c"], p["conv_b_c"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-jnp.exp(p["a_log"])[None, None] * dt)  # (B,S,H) decay
    return xin, b_in, c_in, dt, a, z


def mamba2_apply(p: Params, cfg, x: jnp.ndarray, chunk: int = SSM_CHUNK,
                 return_state: bool = False):
    """SSD matmul-form chunked scan."""
    bsz, s, _ = x.shape
    nh, pdim, n = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xin, b_in, c_in, dt, a, z = _mamba2_front(p, cfg, x)
    xin = constrain(xin, "dp", None, "tp")
    xh = xin.reshape(bsz, s, nh, pdim)                  # (B,S,H,P)
    xdt = xh * dt[..., None]                            # dt-scaled input
    if s % chunk != 0:
        chunk = s                                       # single chunk
    nc = s // chunk

    def reshape_c(t):
        return t.reshape((bsz, nc, chunk) + t.shape[2:])

    xdt_c = jnp.moveaxis(reshape_c(xdt), 1, 0)          # (nc,B,Q,H,P)
    b_c = jnp.moveaxis(reshape_c(b_in), 1, 0)           # (nc,B,Q,N)
    c_c = jnp.moveaxis(reshape_c(c_in), 1, 0)
    la_c = jnp.moveaxis(reshape_c(jnp.log(jnp.maximum(a, 1e-30))), 1, 0)

    qi = jnp.arange(chunk)

    def body(hprev, inp):
        xd, bb, cc, la = inp                            # (B,Q,H,P),(B,Q,N)...
        lac = jnp.cumsum(la, axis=1)                    # (B,Q,H) inclusive
        # intra-chunk
        scores = jnp.einsum("bin,bjn->bij", cc, bb)     # (B,Q,Q)
        decay = jnp.exp(lac[:, :, None] - lac[:, None, :, :])  # (B,Q,Q,H)
        mask = (qi[:, None] >= qi[None, :])[None, :, :, None]
        decay = jnp.where(mask, decay, 0.0)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", scores, decay, xd)
        # inter-chunk (contribution of carried state)
        state_decay = jnp.exp(lac)                      # (B,Q,H)
        y_inter = jnp.einsum("bin,bih,bhpn->bihp",
                             cc, state_decay, hprev)
        # chunk summary → next carry
        tail = jnp.exp(lac[:, -1:, :] - lac)            # (B,Q,H)
        s_c = jnp.einsum("bjn,bjh,bjhp->bhpn", bb, tail, xd)
        hnew = hprev * jnp.exp(lac[:, -1])[..., None, None] + s_c
        return hnew, y_intra + y_inter

    h0 = constrain(jnp.zeros((bsz, nh, pdim, n), jnp.float32),
                   "dp", "tp", None, None)
    h_last, ys = jax.lax.scan(body, h0, (xdt_c, b_c, c_c, la_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, nh, pdim)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(bsz, s, -1)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        k = cfg.d_conv - 1
        return out, {
            "conv_x": (x @ p["in_x"])[:, -k:].astype(jnp.float32),
            "conv_b": (x @ p["in_b"])[:, -k:].astype(jnp.float32),
            "conv_c": (x @ p["in_c"])[:, -k:].astype(jnp.float32),
            "h": h_last,
        }
    return out


def mamba2_apply_ref(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Stepwise-oracle SSD (same front end, per-token recurrence)."""
    bsz, s, _ = x.shape
    nh, pdim, n = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xin, b_in, c_in, dt, a, z = _mamba2_front(p, cfg, x)
    xh = xin.reshape(bsz, s, nh, pdim)
    xdt = xh * dt[..., None]
    b_full = b_in[:, :, None, None, :] * xdt[..., None]     # (B,S,H,P,N)
    a_full = jnp.broadcast_to(a[..., None, None],
                              (bsz, s, nh, pdim, n))
    h = linear_scan_ref(a_full, b_full,
                        jnp.zeros((bsz, nh, pdim, n), jnp.float32))
    y = jnp.einsum("bshpn,bsn->bshp", h, c_in)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(bsz, s, -1)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    return y @ p["out_proj"]


def mamba2_init_cache(cfg, batch: int):
    di, n = cfg.d_inner, cfg.ssm_state
    k = cfg.d_conv - 1
    return {
        "conv_x": jnp.zeros((batch, k, di), jnp.float32),
        "conv_b": jnp.zeros((batch, k, n), jnp.float32),
        "conv_c": jnp.zeros((batch, k, n), jnp.float32),
        "h": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_headdim, n),
                       jnp.float32),
    }


def mamba2_decode(p: Params, cfg, x: jnp.ndarray, cache: Params):
    bsz = x.shape[0]
    di, n = cfg.d_inner, cfg.ssm_state
    nh, pdim = cfg.n_ssm_heads, cfg.ssm_headdim
    xt = x[:, 0]
    z = xt @ p["in_z"]
    dt_raw = xt @ p["in_dt"]
    xr, conv_x = conv1d_step(cache["conv_x"],
                             (xt @ p["in_x"]).astype(jnp.float32),
                             p["conv_w_x"], p["conv_b_x"])
    br, conv_b = conv1d_step(cache["conv_b"],
                             (xt @ p["in_b"]).astype(jnp.float32),
                             p["conv_w_b"], p["conv_b_b"])
    cr, conv_c = conv1d_step(cache["conv_c"],
                             (xt @ p["in_c"]).astype(jnp.float32),
                             p["conv_w_c"], p["conv_b_c"])
    xin = jax.nn.silu(xr).reshape(bsz, nh, pdim)
    b_in = jax.nn.silu(br)
    c_in = jax.nn.silu(cr)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["a_log"])[None] * dt)                     # (B,H)
    xdt = xin * dt[..., None]
    h = cache["h"] * a[..., None, None] \
        + b_in[:, None, None, :] * xdt[..., None]
    y = jnp.einsum("bhpn,bn->bhp", h, c_in) \
        + p["d_skip"][None, :, None] * xin
    y = y.reshape(bsz, -1)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], {
        "conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c, "h": h}
