"""LM assembly: init / forward / loss / prefill / decode for every assigned
architecture family.

Layer stacks are parameter-stacked (leading L axis) and consumed with
``lax.scan`` so the HLO holds one traced layer body regardless of depth —
essential for tractable 512-device dry-run compiles.  Per-layer remat
(``jax.checkpoint``) bounds activation memory.

Families:
  dense   — pre-norm GQA + SwiGLU (phi4 / starcoder2 / granite / qwen3)
  moe     — GQA or MLA attention + routed experts (kimi-k2 / deepseek-v3)
  ssm     — Mamba1 trunk (falcon-mamba)
  hybrid  — Mamba2 trunk + shared attention blocks every k layers (zamba2)
  encdec  — Whisper backbone (stub frame embeddings for the encoder)
  vlm     — InternVL backbone (stub patch embeddings prepended to text)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import mla as mla_mod
from .scan_util import scan_layers as _scan_or_unroll
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (attention, attention_decode, attention_init,
                     cross_attention, dense_init, mlp, mlp_init, rmsnorm,
                     rmsnorm_init, sinusoidal_pos)
from repro.dist.act_sharding import constrain

Params = Dict[str, Any]


# =============================================================================
# init
# =============================================================================

def _embed_init(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32)
                 * d ** -0.5).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], d, cfg.vocab, dt)
    return p


def _dense_layer_init(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model),
        "attn": (mla_mod.mla_init(ks[0], cfg) if cfg.mla
                 else attention_init(ks[0], cfg)),
        "mlp_norm": rmsnorm_init(cfg.d_model),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype)),
    }


def _moe_layer_init(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model),
        "attn": (mla_mod.mla_init(ks[0], cfg) if cfg.mla
                 else attention_init(ks[0], cfg)),
        "mlp_norm": rmsnorm_init(cfg.d_model),
        "moe": moe_mod.moe_init(ks[1], cfg),
    }


def _ssm_layer_init(key, cfg) -> Params:
    return {
        "norm": rmsnorm_init(cfg.d_model),
        "mamba": ssm_mod.mamba1_init(key, cfg),
    }


def _hybrid_layer_init(key, cfg) -> Params:
    return {
        "norm": rmsnorm_init(cfg.d_model),
        "mamba": ssm_mod.mamba2_init(key, cfg),
    }


def _shared_cfg(cfg):
    """Zamba2 shared block runs on the concat width 2·d."""
    d2 = 2 * cfg.d_model
    return dataclasses.replace(cfg, d_model=d2, head_dim=d2 // cfg.n_heads)


def _shared_block_init(key, cfg) -> Params:
    scfg = _shared_cfg(cfg)
    ks = jax.random.split(key, 2)
    return {
        "norm": rmsnorm_init(scfg.d_model),
        "attn": attention_init(ks[0], scfg),
        "mlp_norm": rmsnorm_init(scfg.d_model),
        "mlp": mlp_init(ks[1], scfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype)),
    }


def _stack_init(layer_init, key, cfg, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(k, cfg))(keys)


def _encdec_layer_init(key, cfg, cross: bool) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "attn_norm": rmsnorm_init(cfg.d_model),
        "attn": attention_init(ks[0], cfg),
        "mlp_norm": rmsnorm_init(cfg.d_model),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype)),
    }
    if cross:
        p["cross_norm"] = rmsnorm_init(cfg.d_model)
        p["cross"] = attention_init(ks[2], cfg)
    return p


def init_params(key, cfg) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"embed": _embed_init(ks[0], cfg),
                 "final_norm": rmsnorm_init(cfg.d_model)}
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stack_init(_dense_layer_init, ks[1], cfg, cfg.n_layers)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            p["dense_layers"] = _stack_init(_dense_layer_init, ks[1], cfg, nd)
        p["moe_layers"] = _stack_init(_moe_layer_init, ks[2], cfg,
                                      cfg.n_layers - nd)
    elif fam == "ssm":
        p["layers"] = _stack_init(_ssm_layer_init, ks[1], cfg, cfg.n_layers)
    elif fam == "hybrid":
        p["layers"] = _stack_init(_hybrid_layer_init, ks[1], cfg,
                                  cfg.n_layers)
        p["shared"] = _stack_init(_shared_block_init, ks[2], cfg,
                                  cfg.n_shared_blocks)
        n_sites = cfg.n_layers // cfg.shared_attn_every
        d2 = 2 * cfg.d_model
        p["site_proj"] = (jax.random.normal(
            ks[3], (n_sites, d2, cfg.d_model), jnp.float32)
            * d2 ** -0.5).astype(jnp.dtype(cfg.dtype))
    elif fam == "encdec":
        p["enc_layers"] = _stack_init(
            functools.partial(_encdec_layer_init, cross=False),
            ks[1], cfg, cfg.enc_layers)
        p["dec_layers"] = _stack_init(
            functools.partial(_encdec_layer_init, cross=True),
            ks[2], cfg, cfg.n_layers)
        p["enc_norm"] = rmsnorm_init(cfg.d_model)
    else:
        raise ValueError(fam)
    return p


# =============================================================================
# forward
# =============================================================================

def _dense_block(p, cfg, x, positions):
    if cfg.mla:
        a = mla_mod.mla_attention(p["attn"], cfg,
                                  rmsnorm(p["attn_norm"], x, cfg.norm_eps),
                                  positions)
    else:
        a = attention(p["attn"], cfg,
                      rmsnorm(p["attn_norm"], x, cfg.norm_eps), positions)
    x = x + a
    x = x + mlp(p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x


def _moe_block(p, cfg, x, positions):
    if cfg.mla:
        a = mla_mod.mla_attention(p["attn"], cfg,
                                  rmsnorm(p["attn_norm"], x, cfg.norm_eps),
                                  positions)
    else:
        a = attention(p["attn"], cfg,
                      rmsnorm(p["attn_norm"], x, cfg.norm_eps), positions)
    x = x + a
    y, aux = moe_mod.moe_apply(p["moe"], cfg,
                               rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x + y, aux


def _scan_layers(block_fn, stack, x, *args, remat=True, cfg=None):
    fn = block_fn
    if remat:
        fn = jax.checkpoint(block_fn)

    def body(h, layer_p):
        # sequence-parallel residual: S over 'model' between layers
        # (§Perf iteration 3) — norms are per-token so SP is transparent
        h = constrain(h, "dp", "tp", None)
        return fn(layer_p, h, *args), None

    x, _ = _scan_or_unroll(cfg, body, x, stack)
    return x


def _scan_layers_aux(block_fn, stack, x, *args, remat=True, cfg=None):
    fn = block_fn
    if remat:
        fn = jax.checkpoint(block_fn)

    def body(carry, layer_p):
        h, aux = carry
        h = constrain(h, "dp", None, None)
        h, a = fn(layer_p, h, *args)
        return (h, aux + a), None

    (x, aux), _ = _scan_or_unroll(
        cfg, body, (x, jnp.zeros((), jnp.float32)), stack)
    return x, aux


def _hybrid_trunk(params, cfg, x, positions, remat=True):
    """Mamba2 trunk with shared attention every k layers (zamba2)."""
    every = cfg.shared_attn_every
    n_sites = cfg.n_layers // every
    n_body = n_sites * every
    emb0 = x
    scfg = _shared_cfg(cfg)

    def mamba_block(layer_p, h):
        return h + ssm_mod.mamba2_apply(
            layer_p["mamba"], cfg, rmsnorm(layer_p["norm"], h, cfg.norm_eps))

    mb = jax.checkpoint(mamba_block) if remat else mamba_block

    def shared_apply(shared_p, site_proj, h):
        cat = jnp.concatenate([h, emb0], axis=-1)       # (B,S,2d)
        u = cat + attention(shared_p["attn"], scfg,
                            rmsnorm(shared_p["norm"], cat, cfg.norm_eps),
                            positions)
        u = u + mlp(shared_p["mlp"],
                    rmsnorm(shared_p["mlp_norm"], u, cfg.norm_eps))
        return h + u @ site_proj                        # project 2d → d

    sa = jax.checkpoint(shared_apply) if remat else shared_apply

    # reshape the first n_sites*every layers into (n_sites, every, ...)
    seg_stack = jax.tree.map(
        lambda a: a[:n_body].reshape((n_sites, every) + a.shape[1:]),
        params["layers"])
    tail_stack = jax.tree.map(lambda a: a[n_body:], params["layers"])

    def segment(h, seg):
        seg_layers, site_proj, site_idx = seg

        def inner(hh, lp):
            return mb(lp, hh), None

        h, _ = _scan_or_unroll(cfg, inner, h, seg_layers)
        block_idx = site_idx % cfg.n_shared_blocks
        shared_p = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, block_idx, 0,
                                                   keepdims=False),
            params["shared"])
        h = sa(shared_p, site_proj, h)
        return h, None

    x, _ = _scan_or_unroll(cfg, segment, x,
                           (seg_stack, params["site_proj"],
                            jnp.arange(n_sites)))

    def tail(h, lp):
        return mb(lp, h), None

    x, _ = _scan_or_unroll(cfg, tail, x, tail_stack)
    return x


def forward(params: Params, cfg, tokens: jnp.ndarray,
            extra: Optional[Dict[str, jnp.ndarray]] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B,S) → (hidden (B,S',d), aux loss).  For vlm, S' = V + S;
    for encdec, tokens are decoder tokens and extra['frames'] feeds the
    encoder."""
    extra = extra or {}
    b, s = tokens.shape
    x = constrain(params["embed"]["tok"][tokens], "dp", None, None)
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family
    if fam == "vlm":
        vis = extra["vis_embeds"].astype(x.dtype)       # (B,V,d) stub
        x = jnp.concatenate([vis, x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    if fam in ("dense", "vlm"):
        x = _scan_layers(
            lambda p_, h_, pos_: _dense_block(p_, cfg, h_, pos_),
            params["layers"], x, positions, remat=cfg.remat, cfg=cfg)
    elif fam == "moe":
        if cfg.first_dense_layers:
            x = _scan_layers(
                lambda p_, h_, pos_: _dense_block(p_, cfg, h_, pos_),
                params["dense_layers"], x, positions, remat=cfg.remat,
                cfg=cfg)
        x, aux = _scan_layers_aux(
            lambda p_, h_, pos_: _moe_block(p_, cfg, h_, pos_),
            params["moe_layers"], x, positions, remat=cfg.remat, cfg=cfg)
    elif fam == "ssm":
        x = _scan_layers(
            lambda p_, h_: h_ + ssm_mod.mamba1_apply(
                p_["mamba"], cfg, rmsnorm(p_["norm"], h_, cfg.norm_eps)),
            params["layers"], x, remat=cfg.remat, cfg=cfg)
    elif fam == "hybrid":
        x = _hybrid_trunk(params, cfg, x, positions, remat=cfg.remat)
    elif fam == "encdec":
        frames = extra["frames"].astype(x.dtype)        # (B,F,d) stub
        e = frames + sinusoidal_pos(frames.shape[1],
                                    cfg.d_model).astype(x.dtype)

        def enc_block(p_, h_):
            h_ = h_ + attention(
                p_["attn"], dataclasses.replace(cfg, attn_chunk=0),
                rmsnorm(p_["attn_norm"], h_, cfg.norm_eps),
                jnp.broadcast_to(jnp.arange(h_.shape[1]), h_.shape[:2]))
            return h_ + mlp(p_["mlp"], rmsnorm(p_["mlp_norm"], h_,
                                               cfg.norm_eps))

        e = _scan_layers(enc_block, params["enc_layers"], e,
                         remat=cfg.remat, cfg=cfg)
        e = rmsnorm(params["enc_norm"], e, cfg.norm_eps)
        x = x + sinusoidal_pos(s, cfg.d_model).astype(x.dtype)

        def dec_block(p_, h_, pos_):
            h_ = h_ + attention(p_["attn"], cfg,
                                rmsnorm(p_["attn_norm"], h_, cfg.norm_eps),
                                pos_)
            h_ = h_ + cross_attention(p_["cross"], cfg,
                                      rmsnorm(p_["cross_norm"], h_,
                                              cfg.norm_eps), e)
            return h_ + mlp(p_["mlp"], rmsnorm(p_["mlp_norm"], h_,
                                               cfg.norm_eps))

        x = _scan_layers(dec_block, params["dec_layers"], x, positions,
                         remat=cfg.remat, cfg=cfg)
    else:
        raise ValueError(fam)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def logits_fn(params: Params, cfg, hidden: jnp.ndarray) -> jnp.ndarray:
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["embed"]["head"])
    return hidden @ head


def loss_fn(params: Params, cfg, batch: Dict[str, jnp.ndarray],
            aux_coef: float = 0.01) -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross entropy (+ MoE aux).  batch: tokens (B,S),
    loss_mask (B,S) optional, plus modality extras."""
    tokens = batch["tokens"]
    hidden, aux = forward(params, cfg, tokens, extra=batch)
    if cfg.family == "vlm":                      # drop visual positions
        hidden = hidden[:, cfg.n_vis_tokens:]
    logits = constrain(logits_fn(params, cfg, hidden),
                       "dp", None, "tp").astype(jnp.float32)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = batch.get("loss_mask",
                     jnp.ones_like(tokens, jnp.float32))
    mask = mask * jnp.concatenate(
        [jnp.ones_like(tokens[:, :-1], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll) / denom
    z_loss = 1e-4 * jnp.sum((lse * mask) ** 2) / denom
    loss = ce + aux_coef * aux + z_loss
    return loss, {"ce": ce, "aux": aux, "z": z_loss,
                  "ntok": jnp.sum(mask)}
