"""Multi-head Latent Attention (DeepSeek-V3).

Q and KV pass through low-rank bottlenecks; the decode cache stores only the
compressed latent (kv_lora_rank) plus the shared RoPE key — the MLA memory
win.  The decode path uses the *weight-absorbed* form: scores are computed
directly against the compressed cache (q absorbed through W_uk), and the
context is re-expanded through W_uv after the softmax.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, rmsnorm_init, rope, sdpa_chunked, sdpa_full

Params = Dict[str, Any]


def mla_init(key, cfg) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    qk_hd = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq_a": dense_init(ks[0], d, cfg.q_lora_rank, dt),
        "q_norm": rmsnorm_init(cfg.q_lora_rank),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, h * qk_hd, dt),
        "wkv_a": dense_init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dt),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank),
        "wkv_b": dense_init(ks[3], cfg.kv_lora_rank,
                            h * (cfg.qk_nope_dim + cfg.v_head_dim), dt),
        "wo": dense_init(ks[4], h * cfg.v_head_dim, d, dt),
    }


def _mla_q(p: Params, cfg, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_hd = cfg.qk_nope_dim + cfg.qk_rope_dim
    cq = rmsnorm(p["q_norm"], x @ p["wq_a"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, s, h, qk_hd)
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p: Params, cfg, x, positions):
    kv_a = x @ p["wkv_a"]
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., :cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = rope(kv_a[..., cfg.kv_lora_rank:][:, :, None, :], positions,
                  cfg.rope_theta)[:, :, 0]          # (B,S,rope) shared head
    return c_kv, k_rope


def mla_attention(p: Params, cfg, x: jnp.ndarray, positions,
                  return_latent: bool = False):
    """Full-sequence causal MLA (training / prefill math)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_kv_latent(p, cfg, x, positions)
    kv = (c_kv @ p["wkv_b"]).reshape(b, s, h,
                                     cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope = kv[..., :cfg.qk_nope_dim]
    v = kv[..., cfg.qk_nope_dim:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, cfg.qk_rope_dim))], axis=-1)
    if cfg.attn_chunk and s > cfg.attn_chunk and s % cfg.attn_chunk == 0:
        o = sdpa_chunked(q, k, v, cfg.attn_chunk)
    else:
        o = sdpa_full(q, k, v)
    out = o.reshape(b, s, -1) @ p["wo"]
    if return_latent:
        return out, (c_kv, k_rope)
    return out


def mla_init_cache(cfg, batch: int, max_seq: int):
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank),
                          jnp.dtype(cfg.dtype)),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim),
                            jnp.dtype(cfg.dtype)),
    }


def mla_prefill_cache(p: Params, cfg, x, positions):
    """Latents for the whole prompt (stored compressed)."""
    return _mla_kv_latent(p, cfg, x, positions)


def mla_decode(p: Params, cfg, x: jnp.ndarray, cache: Params,
               pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """Weight-absorbed single-token decode.  x (B,1,d), pos (B,)."""
    b = x.shape[0]
    h = cfg.n_heads
    nope, vd, rd = cfg.qk_nope_dim, cfg.v_head_dim, cfg.qk_rope_dim
    lat = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(p, cfg, x, pos[:, None])   # (B,1,H,·)
    c_new, r_new = _mla_kv_latent(p, cfg, x, pos[:, None])
    c_kv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0)))(cache["c_kv"], c_new, pos)
    k_rope = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0)))(cache["k_rope"], r_new, pos)

    w_uk = p["wkv_b"].reshape(lat, h, nope + vd)[..., :nope]   # (lat,H,nope)
    w_uv = p["wkv_b"].reshape(lat, h, nope + vd)[..., nope:]   # (lat,H,vd)
    # absorb: q_eff (B,1,H,lat)
    q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)
    scores = (jnp.einsum("bqhl,bsl->bhqs", q_eff, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhr,bsr->bhqs", q_rope, k_rope,
                           preferred_element_type=jnp.float32))
    scores = scores * (nope + rd) ** -0.5
    mask = jnp.arange(c_kv.shape[1])[None, :] <= pos[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bsl->bqhl", w, c_kv)        # (B,1,H,lat)
    o = jnp.einsum("bqhl,lhv->bqhv", ctx, w_uv)        # (B,1,H,vd)
    out = o.reshape(b, 1, -1) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
