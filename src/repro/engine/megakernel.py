"""Grid-parallel Pallas megakernels: a ragged task-table walk per family.

Each task *family* (tiled QR, Barnes-Hut, the pipeline F/B/U synthesizer)
gets one Pallas kernel that walks a ragged (CSR) descriptor table as a
real **grid** over item blocks: each write-colored *sub-phase* is chunked
into blocks of ≤ ``block_items`` contiguous work items, the grid iterates
the blocks phase-major (exactly as ragged as the phases — zero inert
programs, zero padding rows), and every grid program runs a short
in-kernel ``fori_loop`` over its block, branching on each row's engine
type with ``lax.switch`` (exllamav3-style type fusion).  Descriptor rows
and block bounds are scalar-prefetched
(``pltpu.PrefetchScalarGridSpec``), so each program reads its item range
and drives its gathers from SMEM-resident integers.  The walk does
exactly ``items`` rows of work — the padded slab layout this replaces did
``rounds × max_width``.  Layout, the type-branch contract and the
coloring/visibility rules are documented in DESIGN.md §Engine ("Ragged
tables & grid walk").

Contract highlights (see the design doc for the full statement):

* State buffers are passed in and aliased to the outputs
  (``input_output_aliases``) with whole-array blocks whose index maps are
  constant, so the state block is resident across all grid programs; the
  first grid program copies the input refs into the output refs
  (``_init_state`` — interpret mode seeds aliased outputs anyway, but
  compiled backends leave output windows undefined until written), and
  every branch then loads *and* stores through the output refs, so items
  observe all earlier programs' writes.
* Blocks never span a phase boundary, so phase-major block order
  serializes exactly the item pairs that touch a common state row — the
  write coloring (``core.plan.color_phases``) guarantees items of one
  phase read/write disjoint rows, so a phase's programs are safe to
  execute in any order or in parallel (on a multi-core TPU, a phase's
  block range is the dimension a parallel ``dimension_semantics`` walk
  may split).  Because the coloring preserves per-destination item order,
  read-modify-write accumulation (Barnes-Hut ``+=``, pipeline grad slabs)
  produces the same bit patterns as the sequential walk it replaced.
* Each family keeps a no-op engine type as the **last** ``lax.switch``
  branch, so a clamped out-of-range type degrades to a no-op rather than
  garbage (a lowering-bug guard; tables themselves carry no no-op rows).
* The numerical bodies are the exact value-level functions the per-op
  kernels use (``kernels.qr_tile.kernel.*_math``,
  ``kernels.nbody.kernel.acc_block``) — one source of truth for the math.

On a CPU runtime the kernels run in Pallas interpret mode (same default as
``kernels/*/ops.py``), so CI executes the identical engine code path; the
grid then executes sequentially (phase-major), which the coloring
invariant makes observationally identical to any parallel interleaving of
a phase's blocks.

The per-family ``*_row_access`` maps in this module declare which state
rows each descriptor row reads and writes, in the same keyspace the
kernels address — they are the input to the write coloring in
``descriptors.lower_tables`` and are property-tested against the phase
partition in ``tests/test_engine_properties.py``.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nbody.kernel import acc_block
from repro.kernels.qr_tile.kernel import (apply_qt_math, apply_tsqt_math,
                                          geqrf_math, tsqrf_math)

# QR engine types — intentionally equal to apps.qr.T_* so task types encode
# to themselves; QR_NOOP is the defensive clamp branch (never in a table).
QR_GEQRF, QR_LARFT, QR_TSQRF, QR_SSRFT, QR_NOOP = range(5)
QR_ARG_WIDTH = 3       # rows: [etype, slot0, slot1, slot2] (tile indices)

# Barnes-Hut engine (work-item) types; BH_NOOP is the clamp branch.
(BH_COM_LEAF, BH_COM_INNER, BH_SELF, BH_PP, BH_PC, BH_NOOP) = range(6)
BH_MAX_CHILDREN = 8    # octree fan-out; COM_INNER rows carry 8 child cells
# and ragged PC source lists chunk into rows of 8 cells (pad = zero-mass)
BH_ARG_WIDTH = 1 + BH_MAX_CHILDREN   # rows: [etype, write, a0..a7]

# Pipeline F/B/U engine types; PIPE_NOOP is the clamp branch.  Rows:
# [etype, stage, micro, in_slot, out_slot, first, last] where the slots are
# flat (stage, micro) indices into the stacked activation/cotangent slabs.
PIPE_F, PIPE_B, PIPE_U, PIPE_NOOP = range(4)
PIPE_ARG_WIDTH = 6

# Work items one grid program walks; each sub-phase chunks into
# ceil(phase_len / block_items) ragged blocks (blocks never span a phase
# boundary, so a phase's programs stay mutually conflict-free).
DEFAULT_BLOCK_ITEMS = 8


def _default_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


# ---------------------------------------------------------------------------
# row-access maps (write-coloring inputs): row -> (reads, writes) state keys
# ---------------------------------------------------------------------------

def qr_row_access(row: Sequence[int]) -> Tuple[Tuple, Tuple]:
    """QR keyspace: ``("t", slot)`` tile-stack rows, ``("m", slot)``
    T-factor rows (column-major tile index)."""
    et = row[0]
    if et == QR_GEQRF:
        s0 = row[1]
        return (("t", s0),), (("t", s0), ("m", s0))
    if et == QR_LARFT:
        s0, s1 = row[1], row[2]
        return (("t", s0), ("m", s0), ("t", s1)), (("t", s1),)
    if et == QR_TSQRF:
        s0, s1 = row[1], row[2]
        return (("t", s0), ("t", s1)), (("t", s0), ("t", s1), ("m", s1))
    if et == QR_SSRFT:
        s0, s1, s2 = row[1], row[2], row[3]
        return ((("t", s0), ("m", s0), ("t", s1), ("t", s2)),
                (("t", s1), ("t", s2)))
    return (), ()


def bh_row_access(row: Sequence[int]) -> Tuple[Tuple, Tuple]:
    """Barnes-Hut keyspace: ``("a", leaf_slot)`` acceleration blocks,
    ``("c", cell)`` COM/mass rows.  Particle positions/masses are
    read-only statics and carry no keys."""
    et = row[0]
    if et == BH_COM_LEAF:
        return (), (("c", row[1]),)
    if et == BH_COM_INNER:
        return (tuple(("c", int(c)) for c in row[2:2 + BH_MAX_CHILDREN]),
                (("c", row[1]),))
    if et in (BH_SELF, BH_PP):
        return (), (("a", row[1]),)
    if et == BH_PC:
        return (tuple(("c", int(c)) for c in row[2:2 + BH_MAX_CHILDREN]),
                (("a", row[1]),))
    return (), ()


def pipe_row_access(row: Sequence[int]) -> Tuple[Tuple, Tuple]:
    """Pipeline keyspace: ``("act"|"cot", slot)`` activation/cotangent
    slabs, ``("gw"|"gb", stage)`` grad buffers, ``("loss", micro)`` loss
    rows.  Stage parameters and microbatch inputs are statics."""
    et, s, m, a_in, a_out = row[0], row[1], row[2], row[3], row[4]
    if et == PIPE_F:
        return ((("act", a_in), ("cot", a_out), ("loss", m)),
                (("act", a_out), ("cot", a_out), ("loss", m)))
    if et == PIPE_B:
        return ((("act", a_in), ("act", a_out), ("cot", a_out),
                 ("gw", s), ("gb", s), ("cot", a_in)),
                (("gw", s), ("gb", s), ("cot", a_in)))
    if et == PIPE_U:
        return ((("gw", s), ("gb", s)), (("gw", s), ("gb", s)))
    return (), ()


# ---------------------------------------------------------------------------
# grid-walk plumbing shared by the three families
# ---------------------------------------------------------------------------

def _blocks_of(phase_bounds: Tuple[int, ...], block_items: int) -> Tuple:
    """Chunk each phase ``[phase_bounds[p], phase_bounds[p+1])`` into
    blocks of ≤ ``block_items`` contiguous work items — one grid program
    each, emitted phase-major so phase order is preserved by the grid walk
    and no program ever spans a phase boundary.  The blocking is exactly
    as ragged as the phases: zero inert programs."""
    blocks = []
    for b0, b1 in zip(phase_bounds, phase_bounds[1:]):
        for s in range(int(b0), int(b1), block_items):
            blocks.append((s, min(s + block_items, int(b1))))
    return tuple(blocks)


def _walk_block(bounds_ref, body) -> None:
    """Run ``body(q, carry)`` over this grid program's work items
    (``bounds_ref[t] = [start, end)`` for program ``t``)."""
    t = pl.program_id(0)
    jax.lax.fori_loop(bounds_ref[t, 0], bounds_ref[t, 1], body, 0)


def _init_state(in_refs, out_refs) -> None:
    """Copy the aliased state into the output refs on the first grid
    program.  Interpret mode already seeds aliased outputs with the input
    values, but compiled backends leave output windows undefined until
    written — the guarded copy makes the visibility contract explicit
    everywhere (program 0 runs first; the constant-index state block then
    stays resident for the rest of the grid)."""
    @pl.when(pl.program_id(0) == 0)
    def _():
        for i_ref, o_ref in zip(in_refs, out_refs):
            o_ref[...] = i_ref[...]


def _grid_walk(kernel, desc, block_bounds, statics, buffers,
               interpret: bool):
    """One ``pallas_call`` walking ``desc`` over a flat grid of ragged
    item blocks: ``block_bounds``/``desc`` scalar-prefetched (SMEM
    integers drive the loop bounds and gathers), statics read-only, state
    buffers aliased input→output with constant whole-array blocks
    (resident across programs, so later programs observe earlier writes).
    Blocks are phase-major: programs of one phase touch pairwise-disjoint
    state rows (the write-coloring invariant) and may execute in any
    order or concurrently; phase order itself is what serializes the
    conflicting pairs."""
    statics = tuple(statics)
    buffers = tuple(buffers)

    def full(a):
        return pl.BlockSpec(a.shape,
                            lambda t, *_, nd=a.ndim: (0,) * nd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(block_bounds.shape[0],),
        in_specs=[full(a) for a in statics + buffers],
        out_specs=tuple(full(a) for a in buffers),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                        for a in buffers),
        input_output_aliases={2 + len(statics) + i: i
                              for i in range(len(buffers))},
        interpret=interpret,
    )(block_bounds, desc, *statics, *buffers)


# ---------------------------------------------------------------------------
# tiled QR family
# ---------------------------------------------------------------------------

def _qr_kernel(bounds_ref, desc_ref, tiles_in, tmat_in, tiles_ref, tmat_ref):
    _init_state((tiles_in, tmat_in), (tiles_ref, tmat_ref))

    def tile(ref, i):
        return pl.load(ref, (pl.ds(i, 1), slice(None), slice(None)))[0]

    def put(ref, i, v):
        pl.store(ref, (pl.ds(i, 1), slice(None), slice(None)), v[None])

    def body(q, carry):
        s0 = desc_ref[q, 1]
        s1 = desc_ref[q, 2]
        s2 = desc_ref[q, 3]

        def geqrf():      # [kk] — factor the diagonal tile, stash T
            rv, _, t = geqrf_math(tile(tiles_ref, s0))
            put(tiles_ref, s0, rv)
            put(tmat_ref, s0, t)
            return 0

        def larft():      # [kk, kj] — apply Qᵀ of the diagonal tile
            out = apply_qt_math(tile(tiles_ref, s0), tile(tmat_ref, s0),
                                tile(tiles_ref, s1))
            put(tiles_ref, s1, out)
            return 0

        def tsqrf():      # [kk, ik] — R stacked over the rect tile; V
            a0 = tile(tiles_ref, s0)       # stays below kk's diagonal
            r1, v2, _, t = tsqrf_math(jnp.triu(a0), tile(tiles_ref, s1))
            put(tiles_ref, s0, jnp.triu(r1) + jnp.tril(a0, -1))
            put(tiles_ref, s1, v2)
            put(tmat_ref, s1, t)
            return 0

        def ssrft():      # [ik, kj, ij] — apply the (I; V2) reflector
            o1, o2 = apply_tsqt_math(tile(tiles_ref, s0),
                                     tile(tmat_ref, s0),
                                     tile(tiles_ref, s1),
                                     tile(tiles_ref, s2))
            put(tiles_ref, s1, o1)
            put(tiles_ref, s2, o2)
            return 0

        def noop():
            return 0

        jax.lax.switch(desc_ref[q, 0], (geqrf, larft, tsqrf, ssrft, noop))
        return carry

    _walk_block(bounds_ref, body)


@functools.lru_cache(maxsize=None)
def qr_round_fn(interpret: Optional[bool] = None,
                block_items: int = DEFAULT_BLOCK_ITEMS):
    """Walk executor for the QR family:
    ``(desc, phase_bounds, (), (tiles, tmat)) -> (tiles, tmat)``.
    ``phase_bounds`` are the static sub-phase boundaries of the rows in
    ``desc``; ``tiles``/``tmat`` are (ntiles, b, b) stacks in column-major
    tile-index order; ``tmat[kk]`` holds the DGEQRF T factor and
    ``tmat[ik]`` the DTSQRF one (disjoint indices, one buffer).  Cached
    per (interpret, block_items) so the runner's jit cache is shared."""
    interp = _default_interpret(interpret)

    def round_fn(desc, phase_bounds, statics, buffers):
        del statics
        bounds = jnp.asarray(_blocks_of(phase_bounds, block_items),
                             jnp.int32)
        return _grid_walk(_qr_kernel, desc, bounds, (), buffers, interp)

    return round_fn


# ---------------------------------------------------------------------------
# Barnes-Hut family
# ---------------------------------------------------------------------------

def _bh_kernel(bounds_ref, desc_ref, xs_ref, ms_ref, acc_in, com_in, cm_in,
               acc_ref, com_ref, cm_ref, *, eps):
    _init_state((acc_in, com_in, cm_in), (acc_ref, com_ref, cm_ref))
    npart = xs_ref.shape[2]
    gi = jax.lax.broadcasted_iota(jnp.int32, (npart, 1), 0)
    gj = jax.lax.broadcasted_iota(jnp.int32, (1, npart), 1)

    def leaf_x(i):                  # (3, P) padded particle block
        return pl.load(xs_ref, (pl.ds(i, 1), slice(None), slice(None)))[0]

    def leaf_m(i):                  # (P,) zero-padded masses
        return pl.load(ms_ref, (pl.ds(i, 1), slice(None)))[0]

    def gather_cells(idx):          # (K,) cell ids → (K,3) coms, (K,) masses
        # per-slot dynamic-slice gathers, NOT a one-hot matmul over the
        # whole com array: the kernel must read exactly the ≤8 rows that
        # bh_row_access declares, or the write coloring could co-phase
        # this item with a writer of an undeclared cell row
        xs_sel = jnp.stack(
            [pl.load(com_ref, (pl.ds(idx[k], 1), slice(None)))[0]
             for k in range(BH_MAX_CHILDREN)])
        m_sel = jnp.stack(
            [pl.load(cm_ref, (pl.ds(idx[k], 1), slice(None)))[0, 0]
             for k in range(BH_MAX_CHILDREN)])
        return xs_sel, m_sel

    def add_acc(i, delta):          # acc[i] += delta, read-modify-write
        cur = pl.load(acc_ref, (pl.ds(i, 1), slice(None), slice(None)))
        pl.store(acc_ref, (pl.ds(i, 1), slice(None), slice(None)),
                 cur + delta[None])

    def pair_delta(xi, xj, mj, mask_diag=False):
        dx0, dx1, dx2, w = acc_block(xi, xj, mj.reshape(1, -1), eps)
        if mask_diag:
            w = jnp.where(gi == gj, jnp.zeros_like(w), w)
        return jnp.stack([jnp.sum(dx0 * w, axis=1),
                          jnp.sum(dx1 * w, axis=1),
                          jnp.sum(dx2 * w, axis=1)])

    def put_com(w, c, tot):
        pl.store(com_ref, (pl.ds(w, 1), slice(None)), c[None])
        pl.store(cm_ref, (pl.ds(w, 1), slice(None)), tot.reshape(1, 1))

    def body(q, carry):
        w = desc_ref[q, 1]
        s = desc_ref[q, 2]

        def cell_slots():      # the 8 padded cell-id slots of this row
            return pl.load(desc_ref,
                           (pl.ds(q, 1), pl.ds(2, BH_MAX_CHILDREN)))[0]

        def com_leaf():   # [cell, leaf] — mass-weighted mean of the block
            x, m = leaf_x(s), leaf_m(s)
            tot = jnp.sum(m)
            put_com(w, (x @ m) / jnp.maximum(tot, 1e-30), tot)
            return 0

        def com_inner():  # [cell, c0..c7] — combine children's COMs
            xs_sel, m_sel = gather_cells(cell_slots())
            tot = jnp.sum(m_sel)
            put_com(w, (xs_sel.T @ m_sel) / jnp.maximum(tot, 1e-30), tot)
            return 0

        def self_():      # [leaf] — all pairs within one block
            x, m = leaf_x(w), leaf_m(w)
            add_acc(w, pair_delta(x, x, m, mask_diag=True))
            return 0

        def pp():         # [leaf_i, leaf_j] — one direction of a pair block
            add_acc(w, pair_delta(leaf_x(w), leaf_x(s), leaf_m(s)))
            return 0

        def pc():         # [leaf, s0..s7] — leaf against ≤8 COM sources
            xs_sel, m_sel = gather_cells(cell_slots())
            add_acc(w, pair_delta(leaf_x(w), xs_sel.T, m_sel))
            return 0

        def noop():
            return 0

        jax.lax.switch(desc_ref[q, 0],
                       (com_leaf, com_inner, self_, pp, pc, noop))
        return carry

    _walk_block(bounds_ref, body)


@functools.lru_cache(maxsize=None)
def bh_round_fn(eps: float, interpret: Optional[bool] = None,
                block_items: int = DEFAULT_BLOCK_ITEMS):
    """Walk executor for the Barnes-Hut family:
    ``(desc, phase_bounds, (xs, ms), (acc, com, cmass)) ->
    (acc, com, cmass)``.  ``xs``/``ms`` are (L, 3, P)/(L, P)
    zero-mass-padded leaf blocks (read-only); ``com``/``cmass`` carry one
    extra zero row as the gather pad target — ragged COM-source lists
    arrive pre-chunked into ≤8-source PC rows, so there is no side table.
    Cached per (eps, interpret, block_items) so the runner's jit cache is
    shared."""
    interp = _default_interpret(interpret)
    kern = functools.partial(_bh_kernel, eps=float(eps))

    def round_fn(desc, phase_bounds, statics, buffers):
        bounds = jnp.asarray(_blocks_of(phase_bounds, block_items),
                             jnp.int32)
        return _grid_walk(kern, desc, bounds, statics, buffers, interp)

    return round_fn


# ---------------------------------------------------------------------------
# pipeline F/B/U family (the canonical uniform dense stage, see
# repro.pipeline.exec: stage = tanh(x @ w + b), loss = mean squared error)
# ---------------------------------------------------------------------------

def _pipe_kernel(bounds_ref, desc_ref, w_ref, b_ref, x_ref, y_ref,
                 acts_in, cots_in, gw_in, gb_in, loss_in,
                 acts_ref, cots_ref, gw_ref, gb_ref, loss_ref, *, inv_m):
    _init_state((acts_in, cots_in, gw_in, gb_in, loss_in),
                (acts_ref, cots_ref, gw_ref, gb_ref, loss_ref))
    bt, dim = acts_ref.shape[1], acts_ref.shape[2]
    inv_numel = 1.0 / (bt * dim)      # MSE mean over one microbatch output

    def blk(ref, i):                  # (Bt, D) slab of a stacked buffer
        return pl.load(ref, (pl.ds(i, 1), slice(None), slice(None)))[0]

    def put(ref, i, v):
        pl.store(ref, (pl.ds(i, 1), slice(None), slice(None)), v[None])

    def row(ref, i):                  # (D,) row of a (S, D) buffer
        return pl.load(ref, (pl.ds(i, 1), slice(None)))[0]

    def body(q, carry):
        s = desc_ref[q, 1]
        m = desc_ref[q, 2]
        a_in = desc_ref[q, 3]         # == a_out (safe dummy) when first
        a_out = desc_ref[q, 4]
        first = desc_ref[q, 5]
        last = desc_ref[q, 6]

        def stage_input():            # x[m] on stage 0, else prev output
            return jnp.where(first > 0, blk(x_ref, m), blk(acts_ref, a_in))

        def fwd():        # acts[s,m] = tanh(in @ w_s + b_s); last: loss+seed
            h = jnp.tanh(stage_input() @ blk(w_ref, s) + row(b_ref, s)[None])
            put(acts_ref, a_out, h)
            diff = h - blk(y_ref, m)
            lcur = pl.load(loss_ref, (pl.ds(m, 1), slice(None)))
            pl.store(loss_ref, (pl.ds(m, 1), slice(None)),
                     jnp.where(last > 0, jnp.sum(diff * diff) * inv_numel,
                               lcur[0, 0]).reshape(1, 1))
            put(cots_ref, a_out,
                jnp.where(last > 0, (2.0 * inv_numel) * diff,
                          blk(cots_ref, a_out)))
            return 0

        def bwd():        # grads[s] += vjp; cotangent flows to stage s-1
            h = blk(acts_ref, a_out)
            gpre = blk(cots_ref, a_out) * (1.0 - h * h)   # tanh' = 1 - y²
            put(gw_ref, s, blk(gw_ref, s) + stage_input().T @ gpre)
            pl.store(gb_ref, (pl.ds(s, 1), slice(None)),
                     (row(gb_ref, s) + jnp.sum(gpre, axis=0))[None])
            put(cots_ref, a_in,
                jnp.where(first > 0, blk(cots_ref, a_in),
                          gpre @ blk(w_ref, s).T))
            return 0

        def upd():        # microbatch averaging; optimizer is the caller's
            put(gw_ref, s, blk(gw_ref, s) * inv_m)
            pl.store(gb_ref, (pl.ds(s, 1), slice(None)),
                     (row(gb_ref, s) * inv_m)[None])
            return 0

        def noop():
            return 0

        jax.lax.switch(desc_ref[q, 0], (fwd, bwd, upd, noop))
        return carry

    _walk_block(bounds_ref, body)


@functools.lru_cache(maxsize=None)
def pipe_round_fn(inv_m: float, interpret: Optional[bool] = None,
                  block_items: int = DEFAULT_BLOCK_ITEMS):
    """Walk executor for the pipeline family:
    ``(desc, phase_bounds, (w, b, x, y), (acts, cots, gw, gb, loss)) ->
    buffers``.  ``w``/``b`` are (S, D, D)/(S, D) stage-parameter stacks,
    ``x``/``y`` (M, Bt, D) microbatch inputs/targets (read-only); the
    kernel-resident state is the stacked stage-activation (``acts``) and
    cotangent (``cots``) slabs — flat (S·M, Bt, D), slot = stage·M +
    micro — plus the grad-accumulation buffers ``gw``/``gb`` and
    per-micro ``loss`` (M, 1).  ``inv_m`` = 1/M is the U branch's
    microbatch averaging.  Cached per (inv_m, interpret, block_items) so
    the runner's jit cache is shared."""
    interp = _default_interpret(interpret)
    kern = functools.partial(_pipe_kernel, inv_m=float(inv_m))

    def round_fn(desc, phase_bounds, statics, buffers):
        bounds = jnp.asarray(_blocks_of(phase_bounds, block_items),
                             jnp.int32)
        return _grid_walk(kern, desc, bounds, statics, buffers, interp)

    return round_fn
