"""Fused-round Pallas megakernels: one kernel launch per round per family.

Each task *family* (tiled QR, Barnes-Hut, the pipeline F/B/U synthesizer)
gets one Pallas kernel that takes
a round's descriptor slab and the family's resident state buffers, walks
the slab with an in-kernel ``fori_loop`` and branches on the engine type of
each row with ``lax.switch`` (exllamav3-style type fusion) — replacing the
N per-type ``pallas_call``s the host rounds mode issues per round with a
single launch whose operands never leave the device.  Layout, the
type-branch contract and the donation/aliasing rules are documented in
DESIGN.md §Engine.

Contract highlights (see the design doc for the full statement):

* State buffers are passed in and aliased to the outputs
  (``input_output_aliases``); the kernel copies them into its output refs
  once, then every branch loads *and* stores through the output refs, so
  items observe all earlier items' writes — read-modify-write accumulation
  (Barnes-Hut ``+=``) and the QR triangular in-place updates are exact.
* Row order within a slab is the host rounds-mode order (ascending task
  type, batch order within a type), so the engine's sequencing is
  observationally identical to ``ExecutionPlan.execute``; conflict-freedom
  of every slab is what makes the rounds independent of *which* items land
  together (property-tested).
* Padding rows carry the family's no-op type — the last ``lax.switch``
  branch, so out-of-range types clamp to a no-op rather than garbage.
* The numerical bodies are the exact value-level functions the per-op
  kernels use (``kernels.qr_tile.kernel.*_math``,
  ``kernels.nbody.kernel.acc_block``) — one source of truth for the math.

On a CPU runtime the kernels run in Pallas interpret mode (same default as
``kernels/*/ops.py``), so CI executes the identical engine code path.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.nbody.kernel import acc_block
from repro.kernels.qr_tile.kernel import (apply_qt_math, apply_tsqt_math,
                                          geqrf_math, tsqrf_math)

# QR engine types — intentionally equal to apps.qr.T_* so task types encode
# to themselves; QR_NOOP pads the slabs (descriptors.lower_tables pad_type).
QR_GEQRF, QR_LARFT, QR_TSQRF, QR_SSRFT, QR_NOOP = range(5)
QR_ARG_WIDTH = 3       # rows: [etype, slot0, slot1, slot2] (tile indices)

# Barnes-Hut engine (work-item) types; BH_NOOP pads.
(BH_COM_LEAF, BH_COM_INNER, BH_SELF, BH_PP, BH_PC, BH_NOOP) = range(6)
BH_MAX_CHILDREN = 8    # octree fan-out; COM_INNER rows carry 8 child cells
# and ragged PC source lists chunk into rows of 8 cells (pad = zero-mass)
BH_ARG_WIDTH = 1 + BH_MAX_CHILDREN   # rows: [etype, write, a0..a7]

# Pipeline F/B/U engine types; PIPE_NOOP pads.  Rows:
# [etype, stage, micro, in_slot, out_slot, first, last] where the slots are
# flat (stage, micro) indices into the stacked activation/cotangent slabs.
PIPE_F, PIPE_B, PIPE_U, PIPE_NOOP = range(4)
PIPE_ARG_WIDTH = 6


def _default_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _full_spec(shape):
    return pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))


# ---------------------------------------------------------------------------
# tiled QR family
# ---------------------------------------------------------------------------

def _qr_kernel(desc_ref, tiles_in, tmat_in, tiles_ref, tmat_ref):
    tiles_ref[...] = tiles_in[...]
    tmat_ref[...] = tmat_in[...]

    def tile(ref, i):
        return pl.load(ref, (pl.ds(i, 1), slice(None), slice(None)))[0]

    def put(ref, i, v):
        pl.store(ref, (pl.ds(i, 1), slice(None), slice(None)), v[None])

    def body(q, carry):
        s0 = desc_ref[q, 1]
        s1 = desc_ref[q, 2]
        s2 = desc_ref[q, 3]

        def geqrf():      # [kk] — factor the diagonal tile, stash T
            rv, _, t = geqrf_math(tile(tiles_ref, s0))
            put(tiles_ref, s0, rv)
            put(tmat_ref, s0, t)
            return 0

        def larft():      # [kk, kj] — apply Qᵀ of the diagonal tile
            out = apply_qt_math(tile(tiles_ref, s0), tile(tmat_ref, s0),
                                tile(tiles_ref, s1))
            put(tiles_ref, s1, out)
            return 0

        def tsqrf():      # [kk, ik] — R stacked over the rect tile; V
            a0 = tile(tiles_ref, s0)       # stays below kk's diagonal
            r1, v2, _, t = tsqrf_math(jnp.triu(a0), tile(tiles_ref, s1))
            put(tiles_ref, s0, jnp.triu(r1) + jnp.tril(a0, -1))
            put(tiles_ref, s1, v2)
            put(tmat_ref, s1, t)
            return 0

        def ssrft():      # [ik, kj, ij] — apply the (I; V2) reflector
            o1, o2 = apply_tsqt_math(tile(tiles_ref, s0),
                                     tile(tmat_ref, s0),
                                     tile(tiles_ref, s1),
                                     tile(tiles_ref, s2))
            put(tiles_ref, s1, o1)
            put(tiles_ref, s2, o2)
            return 0

        def noop():
            return 0

        jax.lax.switch(desc_ref[q, 0], (geqrf, larft, tsqrf, ssrft, noop))
        return carry

    jax.lax.fori_loop(0, desc_ref.shape[0], body, 0)


@functools.lru_cache(maxsize=None)
def qr_round_fn(interpret: Optional[bool] = None):
    """Round executor for the QR family: ``(desc_slab, (), (tiles, tmat))
    -> (tiles, tmat)``.  ``tiles``/``tmat`` are (ntiles, b, b) stacks in
    column-major tile-index order; ``tmat[kk]`` holds the DGEQRF T factor
    and ``tmat[ik]`` the DTSQRF one (disjoint indices, one buffer).  Cached
    per ``interpret`` flag so the runner's jit cache is shared."""
    interp = _default_interpret(interpret)

    def round_fn(desc, statics, buffers):
        del statics
        tiles, tmat = buffers
        return pl.pallas_call(
            _qr_kernel,
            grid=(),
            in_specs=[_full_spec(desc.shape), _full_spec(tiles.shape),
                      _full_spec(tmat.shape)],
            out_specs=(_full_spec(tiles.shape), _full_spec(tmat.shape)),
            out_shape=(jax.ShapeDtypeStruct(tiles.shape, tiles.dtype),
                       jax.ShapeDtypeStruct(tmat.shape, tmat.dtype)),
            input_output_aliases={1: 0, 2: 1},
            interpret=interp,
        )(desc, tiles, tmat)

    return round_fn


# ---------------------------------------------------------------------------
# Barnes-Hut family
# ---------------------------------------------------------------------------

def _bh_kernel(desc_ref, xs_ref, ms_ref, acc_in, com_in, cm_in,
               acc_ref, com_ref, cm_ref, *, eps):
    acc_ref[...] = acc_in[...]
    com_ref[...] = com_in[...]
    cm_ref[...] = cm_in[...]
    dtype = acc_ref.dtype
    npart = xs_ref.shape[2]
    ncell = com_ref.shape[0]        # ncells + 1 (last row = zero-mass pad)
    cell_iota = jax.lax.broadcasted_iota(jnp.int32, (1, ncell), 1)
    gi = jax.lax.broadcasted_iota(jnp.int32, (npart, 1), 0)
    gj = jax.lax.broadcasted_iota(jnp.int32, (1, npart), 1)

    def leaf_x(i):                  # (3, P) padded particle block
        return pl.load(xs_ref, (pl.ds(i, 1), slice(None), slice(None)))[0]

    def leaf_m(i):                  # (P,) zero-padded masses
        return pl.load(ms_ref, (pl.ds(i, 1), slice(None)))[0]

    def gather_cells(idx):          # (K,) cell ids → (K,3) coms, (K,) masses
        onehot = (idx[:, None] == cell_iota).astype(dtype)
        return onehot @ com_ref[...], (onehot @ cm_ref[...])[:, 0]

    def add_acc(i, delta):          # acc[i] += delta, read-modify-write
        cur = pl.load(acc_ref, (pl.ds(i, 1), slice(None), slice(None)))
        pl.store(acc_ref, (pl.ds(i, 1), slice(None), slice(None)),
                 cur + delta[None])

    def pair_delta(xi, xj, mj, mask_diag=False):
        dx0, dx1, dx2, w = acc_block(xi, xj, mj.reshape(1, -1), eps)
        if mask_diag:
            w = jnp.where(gi == gj, jnp.zeros_like(w), w)
        return jnp.stack([jnp.sum(dx0 * w, axis=1),
                          jnp.sum(dx1 * w, axis=1),
                          jnp.sum(dx2 * w, axis=1)])

    def put_com(w, c, tot):
        pl.store(com_ref, (pl.ds(w, 1), slice(None)), c[None])
        pl.store(cm_ref, (pl.ds(w, 1), slice(None)), tot.reshape(1, 1))

    def body(q, carry):
        w = desc_ref[q, 1]
        s = desc_ref[q, 2]

        def cell_slots():      # the 8 padded cell-id slots of this row
            return pl.load(desc_ref,
                           (pl.ds(q, 1), pl.ds(2, BH_MAX_CHILDREN)))[0]

        def com_leaf():   # [cell, leaf] — mass-weighted mean of the block
            x, m = leaf_x(s), leaf_m(s)
            tot = jnp.sum(m)
            put_com(w, (x @ m) / jnp.maximum(tot, 1e-30), tot)
            return 0

        def com_inner():  # [cell, c0..c7] — combine children's COMs
            xs_sel, m_sel = gather_cells(cell_slots())
            tot = jnp.sum(m_sel)
            put_com(w, (xs_sel.T @ m_sel) / jnp.maximum(tot, 1e-30), tot)
            return 0

        def self_():      # [leaf] — all pairs within one block
            x, m = leaf_x(w), leaf_m(w)
            add_acc(w, pair_delta(x, x, m, mask_diag=True))
            return 0

        def pp():         # [leaf_i, leaf_j] — one direction of a pair block
            add_acc(w, pair_delta(leaf_x(w), leaf_x(s), leaf_m(s)))
            return 0

        def pc():         # [leaf, s0..s7] — leaf against ≤8 COM sources
            xs_sel, m_sel = gather_cells(cell_slots())
            add_acc(w, pair_delta(leaf_x(w), xs_sel.T, m_sel))
            return 0

        def noop():
            return 0

        jax.lax.switch(desc_ref[q, 0],
                       (com_leaf, com_inner, self_, pp, pc, noop))
        return carry

    jax.lax.fori_loop(0, desc_ref.shape[0], body, 0)


# ---------------------------------------------------------------------------
# pipeline F/B/U family (the canonical uniform dense stage, see
# repro.pipeline.exec: stage = tanh(x @ w + b), loss = mean squared error)
# ---------------------------------------------------------------------------

def _pipe_kernel(desc_ref, w_ref, b_ref, x_ref, y_ref,
                 acts_in, cots_in, gw_in, gb_in, loss_in,
                 acts_ref, cots_ref, gw_ref, gb_ref, loss_ref, *, inv_m):
    acts_ref[...] = acts_in[...]
    cots_ref[...] = cots_in[...]
    gw_ref[...] = gw_in[...]
    gb_ref[...] = gb_in[...]
    loss_ref[...] = loss_in[...]
    bt, dim = acts_ref.shape[1], acts_ref.shape[2]
    inv_numel = 1.0 / (bt * dim)      # MSE mean over one microbatch output

    def blk(ref, i):                  # (Bt, D) slab of a stacked buffer
        return pl.load(ref, (pl.ds(i, 1), slice(None), slice(None)))[0]

    def put(ref, i, v):
        pl.store(ref, (pl.ds(i, 1), slice(None), slice(None)), v[None])

    def row(ref, i):                  # (D,) row of a (S, D) buffer
        return pl.load(ref, (pl.ds(i, 1), slice(None)))[0]

    def body(q, carry):
        s = desc_ref[q, 1]
        m = desc_ref[q, 2]
        a_in = desc_ref[q, 3]         # == a_out (safe dummy) when first
        a_out = desc_ref[q, 4]
        first = desc_ref[q, 5]
        last = desc_ref[q, 6]

        def stage_input():            # x[m] on stage 0, else prev output
            return jnp.where(first > 0, blk(x_ref, m), blk(acts_ref, a_in))

        def fwd():        # acts[s,m] = tanh(in @ w_s + b_s); last: loss+seed
            h = jnp.tanh(stage_input() @ blk(w_ref, s) + row(b_ref, s)[None])
            put(acts_ref, a_out, h)
            diff = h - blk(y_ref, m)
            lcur = pl.load(loss_ref, (pl.ds(m, 1), slice(None)))
            pl.store(loss_ref, (pl.ds(m, 1), slice(None)),
                     jnp.where(last > 0, jnp.sum(diff * diff) * inv_numel,
                               lcur[0, 0]).reshape(1, 1))
            put(cots_ref, a_out,
                jnp.where(last > 0, (2.0 * inv_numel) * diff,
                          blk(cots_ref, a_out)))
            return 0

        def bwd():        # grads[s] += vjp; cotangent flows to stage s-1
            h = blk(acts_ref, a_out)
            gpre = blk(cots_ref, a_out) * (1.0 - h * h)   # tanh' = 1 - y²
            put(gw_ref, s, blk(gw_ref, s) + stage_input().T @ gpre)
            pl.store(gb_ref, (pl.ds(s, 1), slice(None)),
                     (row(gb_ref, s) + jnp.sum(gpre, axis=0))[None])
            put(cots_ref, a_in,
                jnp.where(first > 0, blk(cots_ref, a_in),
                          gpre @ blk(w_ref, s).T))
            return 0

        def upd():        # microbatch averaging; optimizer is the caller's
            put(gw_ref, s, blk(gw_ref, s) * inv_m)
            pl.store(gb_ref, (pl.ds(s, 1), slice(None)),
                     (row(gb_ref, s) * inv_m)[None])
            return 0

        def noop():
            return 0

        jax.lax.switch(desc_ref[q, 0], (fwd, bwd, upd, noop))
        return carry

    jax.lax.fori_loop(0, desc_ref.shape[0], body, 0)


@functools.lru_cache(maxsize=None)
def pipe_round_fn(inv_m: float, interpret: Optional[bool] = None):
    """Round executor for the pipeline family:
    ``(desc_slab, (w, b, x, y), (acts, cots, gw, gb, loss)) -> buffers``.
    ``w``/``b`` are (S, D, D)/(S, D) stage-parameter stacks, ``x``/``y``
    (M, Bt, D) microbatch inputs/targets (read-only); the kernel-resident
    state is the stacked stage-activation (``acts``) and cotangent
    (``cots``) slabs — flat (S·M, Bt, D), slot = stage·M + micro — plus the
    grad-accumulation buffers ``gw``/``gb`` and per-micro ``loss`` (M, 1).
    ``inv_m`` = 1/M is the U branch's microbatch averaging.  Cached per
    (inv_m, interpret) so the runner's jit cache is shared."""
    interp = _default_interpret(interpret)
    kern = functools.partial(_pipe_kernel, inv_m=float(inv_m))

    def round_fn(desc, statics, buffers):
        w, b, x, y = statics
        acts, cots, gw, gb, loss = buffers
        shapes = (acts, cots, gw, gb, loss)
        return pl.pallas_call(
            kern,
            grid=(),
            in_specs=[_full_spec(desc.shape), _full_spec(w.shape),
                      _full_spec(b.shape), _full_spec(x.shape),
                      _full_spec(y.shape)]
            + [_full_spec(a.shape) for a in shapes],
            out_specs=tuple(_full_spec(a.shape) for a in shapes),
            out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                            for a in shapes),
            input_output_aliases={5: 0, 6: 1, 7: 2, 8: 3, 9: 4},
            interpret=interp,
        )(desc, w, b, x, y, acts, cots, gw, gb, loss)

    return round_fn


@functools.lru_cache(maxsize=None)
def bh_round_fn(eps: float, interpret: Optional[bool] = None):
    """Round executor for the Barnes-Hut family:
    ``(desc_slab, (xs, ms), (acc, com, cmass)) -> (acc, com, cmass)``.
    ``xs``/``ms`` are (L, 3, P)/(L, P) zero-mass-padded leaf blocks
    (read-only); ``com``/``cmass`` carry one extra zero row as the gather
    pad target — ragged COM-source lists arrive pre-chunked into ≤8-source
    PC rows, so there is no side table.  Cached per (eps, interpret) so
    the runner's jit cache is shared."""
    interp = _default_interpret(interpret)
    kern = functools.partial(_bh_kernel, eps=float(eps))

    def round_fn(desc, statics, buffers):
        xs, ms = statics
        acc, com, cm = buffers
        return pl.pallas_call(
            kern,
            grid=(),
            in_specs=[_full_spec(desc.shape), _full_spec(xs.shape),
                      _full_spec(ms.shape), _full_spec(acc.shape),
                      _full_spec(com.shape), _full_spec(cm.shape)],
            out_specs=(_full_spec(acc.shape), _full_spec(com.shape),
                       _full_spec(cm.shape)),
            out_shape=(jax.ShapeDtypeStruct(acc.shape, acc.dtype),
                       jax.ShapeDtypeStruct(com.shape, com.dtype),
                       jax.ShapeDtypeStruct(cm.shape, cm.dtype)),
            input_output_aliases={3: 0, 4: 1, 5: 2},
            interpret=interp,
        )(desc, xs, ms, acc, com, cm)

    return round_fn
