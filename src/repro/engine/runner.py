"""Device-resident plan execution: one jitted host dispatch per plan.

``execute_plan`` runs a ragged :class:`~repro.engine.descriptors.TaskTable`
through a family walk function (``repro.engine.megakernel``) as a single
jitted program — the whole plan becomes one XLA program with zero host
transitions between rounds, and the state buffers are donated so execution
is in-place end to end (DESIGN.md §Engine).

Two dispatch shapes, same single host call:

* per-round (default): one grid-walk ``pallas_call`` per non-empty round,
  unrolled inside the jitted program (each round's CSR slice has its own
  static shape — raggedness costs nothing at run time, empty rounds
  disappear entirely);
* ``fuse_rounds=True``: ONE megakernel launch whose phase grid walks the
  *entire plan* (a single copy-in/copy-out of the state).  Legal because
  the global phase order already serializes rounds; it is the fastest mode
  whenever the family state fits the kernel's memory budget
  (``benchmarks/engine_dispatch.py`` times both and CI keeps
  fused ≤ looped).

On CPU runtimes the megakernels run in Pallas interpret mode, so this is
also the CI path; buffer donation is only requested on backends that
implement it (donation on CPU is a no-op that warns).
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

from .descriptors import TaskTable

# (desc, phase_bounds, statics, buffers) -> buffers; phase_bounds is a
# static tuple of sub-phase boundaries over desc's rows — the megakernel
# factories chunk it into the ragged block grid on the host
RoundFn = Callable[[jnp.ndarray, Tuple[int, ...], Tuple, Tuple], Tuple]

ENGINE_DISPATCHES_PER_PLAN = 1     # the whole point — see BENCH_engine.json

# launch segment: (row_start, row_end, phase_bounds relative to row_start)
Segment = Tuple[int, int, Tuple[int, ...]]


def _round_segments_for(tables: TaskTable, r: int) -> Tuple[Segment, ...]:
    o0 = int(tables.round_offsets[r])
    o1 = int(tables.round_offsets[r + 1])
    if o1 == o0:
        return ()                  # empty rounds lower to no launch at all
    bounds = tables.round_phases(r)
    return ((o0, o1, tuple(int(b) - o0 for b in bounds)),)


def _round_segments(tables: TaskTable) -> Tuple[Segment, ...]:
    return tuple(s for r in range(tables.nr_rounds)
                 for s in _round_segments_for(tables, r))


def _fused_segments(tables: TaskTable) -> Tuple[Segment, ...]:
    if tables.nr_items == 0:
        return ()
    return ((0, tables.nr_items,
             tuple(int(b) for b in tables.phase_offsets)),)


@functools.lru_cache(maxsize=None)
def _segment_runner(round_fn: RoundFn, segments: Tuple[Segment, ...],
                    donate: bool):
    """Jitted executor for a fixed launch layout.  ``round_fn`` must be a
    stable object (the megakernel factories are lru-cached) and
    ``segments`` is derived from host-side table offsets, so repeated
    executions of structurally identical plans share one compilation."""
    def run(desc, statics, buffers):
        for o0, o1, bounds in segments:
            buffers = round_fn(desc[o0:o1], bounds, statics, buffers)
        return buffers

    return jax.jit(run, donate_argnums=(2,) if donate else ())


def execute_plan(tables: TaskTable, round_fn: RoundFn,
                 statics: Sequence, buffers: Sequence, *,
                 fuse_rounds: bool = False,
                 donate: Optional[bool] = None) -> Tuple:
    """Execute a lowered task table.  ``statics`` are read-only family
    inputs (may be empty); ``buffers`` are the mutable state arrays,
    threaded launch to launch and returned.  ``round_fn`` must be a stable
    object (the megakernel factories are lru-cached) so repeated calls hit
    the jit cache."""
    statics = tuple(statics)
    buffers = tuple(buffers)
    if tables.nr_items == 0:
        return buffers
    if donate is None:
        donate = jax.default_backend() in ("tpu", "gpu")
    segments = (_fused_segments(tables) if fuse_rounds
                else _round_segments(tables))
    run = _segment_runner(round_fn, segments, bool(donate))
    reg = _metrics.get_registry()
    reg.counter("engine.plans_executed").inc()
    reg.counter("engine.launch_segments").inc(len(segments))
    reg.counter("engine.items_walked").inc(tables.nr_items)
    tr = _trace.get_tracer()
    if not tr.enabled:
        return run(jnp.asarray(tables.desc), statics, buffers)
    # launch-segment span: tracing forces a device sync so the span covers
    # execution, not just the async dispatch — acceptable observer cost,
    # paid only when a tracer is installed
    t0 = _trace.now()
    out = run(jnp.asarray(tables.desc), statics, buffers)
    jax.block_until_ready(out)
    tr.event_span("engine.execute", t0, _trace.now(), lane="engine",
                  items=tables.nr_items, rounds=tables.nr_rounds,
                  phases=tables.nr_phases, segments=len(segments),
                  fused=fuse_rounds)
    return out


@functools.lru_cache(maxsize=None)
def _item_runner(round_fn: RoundFn):
    """Jitted single-item launch — every item shares the (1, 1+A) shape,
    so one compilation covers the whole per-item measurement pass."""
    def run(desc_row, statics, buffers):
        return round_fn(desc_row, (0, 1), statics, buffers)

    return jax.jit(run)


@dataclass
class RoundTimings:
    """Measured engine times (``measure_round_times``): ``round_s[r]`` is
    round ``r``'s wall time (one grid-walk launch per round, 0.0 for empty
    rounds); ``item_s[q]`` — only with ``per_item=True`` — is flat work
    item ``q``'s wall time (one single-item launch each, mapping to tasks
    through ``TaskTable.tids``, the input to
    ``core.simulator.replay_item_times``).  ``buffers`` is the final state
    of the last measurement pass (identical for both passes — the walks
    differ only in launch granularity)."""
    round_s: List[float]
    item_s: Optional[np.ndarray]
    buffers: Tuple


def measure_round_times(tables: TaskTable, round_fn: RoundFn,
                        statics: Sequence, buffers: Sequence, *,
                        per_item: bool = False) -> RoundTimings:
    """Execute a task table one round at a time, timing each launch
    (blocked on completion) — the measured per-round engine times that
    ``core.simulator.replay_round_times`` feeds back into the discrete-
    event model to validate its makespan prediction against the fused
    single-dispatch execute time (ROADMAP: simulator validation).  With
    ``per_item=True`` an additional pass re-executes the table one *item*
    at a time, giving each task its own measured cost
    (``core.simulator.replay_item_times`` replays those into lane-parallel
    makespans).  Every ragged round shape is pre-run once as compile
    warmup, so the timings are steady-state.

    Caveat on per-item granularity: each single-item launch pays the full
    per-launch overhead (dispatch + state copy-in/out), so on hosts where
    that overhead rivals one item's arithmetic — CPU interpret mode in
    particular — ``item_s`` is an upper bound skewed toward launch cost,
    and the replay validates the *model mechanics* (additivity, lane
    bounds) rather than hardware task costs.  Measuring per-item costs
    worth trusting on real accelerators is the ROADMAP simulator-
    validation item."""
    statics = tuple(statics)
    init = tuple(buffers)
    desc = jnp.asarray(tables.desc)
    runners = {}
    for r in range(tables.nr_rounds):
        segs = _round_segments_for(tables, r)
        runners[r] = (_segment_runner(round_fn, segs, False)
                      if segs else None)

    bufs = init
    for r in range(tables.nr_rounds):          # compile warmup, all shapes
        if runners[r] is not None:
            bufs = runners[r](desc, statics, bufs)
    jax.block_until_ready(bufs)

    tr = _trace.get_tracer()
    round_s: List[float] = []
    bufs = init
    for r in range(tables.nr_rounds):
        if runners[r] is None:
            round_s.append(0.0)
            continue
        t0 = time.perf_counter()
        bufs = runners[r](desc, statics, bufs)
        jax.block_until_ready(bufs)
        t1 = time.perf_counter()
        round_s.append(t1 - t0)
        if tr.enabled:
            tr.event_span("engine.round", t0, t1, lane="engine rounds",
                          round=r,
                          items=int(tables.round_offsets[r + 1]
                                    - tables.round_offsets[r]))

    item_s = None
    if per_item:
        run1 = _item_runner(round_fn)
        if tables.nr_items:
            jax.block_until_ready(
                run1(desc[0:1], statics, init))          # compile warmup
        bufs = init
        item_s = np.zeros(tables.nr_items, np.float64)
        etypes = tables.desc[:, 0]
        for q in range(tables.nr_items):
            t0 = time.perf_counter()
            bufs = run1(desc[q:q + 1], statics, bufs)
            jax.block_until_ready(bufs)
            t1 = time.perf_counter()
            item_s[q] = t1 - t0
            if tr.enabled:
                # the paper's per-task tic/toc, keyed back to tasks
                # through TaskTable.tids — one timeline row, since the
                # measurement pass is by construction sequential
                tr.task(int(tables.tids[q]), int(etypes[q]), 0, t0, t1)
    return RoundTimings(round_s=round_s, item_s=item_s, buffers=bufs)
