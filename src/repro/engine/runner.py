"""Device-resident plan execution: one jitted host dispatch per plan.

``execute_plan`` runs a :class:`~repro.engine.descriptors.TaskTable`
through a family round function (``repro.engine.megakernel``) as a single
jitted ``lax.fori_loop`` over rounds — the whole plan becomes one XLA
program with zero host transitions between rounds, and the state buffers
are donated so execution is in-place end to end (DESIGN.md §Engine).

``fuse_rounds=True`` additionally collapses every round slab into one —
one megakernel launch for the *entire plan* (a single copy-in/copy-out of
the state).  This is legal precisely because slab row order already
serializes rounds and the megakernel walks rows sequentially; it is the
fastest mode whenever the family state fits the kernel's memory budget.

On CPU runtimes the megakernels run in Pallas interpret mode, so this is
also the CI path; buffer donation is only requested on backends that
implement it (donation on CPU is a no-op that warns).
"""

from __future__ import annotations

import functools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .descriptors import TaskTable

RoundFn = Callable[[jnp.ndarray, Tuple, Tuple], Tuple]

ENGINE_DISPATCHES_PER_PLAN = 1     # the whole point — see BENCH_engine.json


def _loop(round_fn: RoundFn, desc, statics, buffers):
    def body(r, bufs):
        return round_fn(desc[r], statics, bufs)
    return jax.lax.fori_loop(0, desc.shape[0], body, buffers)


@functools.partial(jax.jit, static_argnums=0, donate_argnums=3)
def _run_donating(round_fn, desc, statics, buffers):
    return _loop(round_fn, desc, statics, buffers)


@functools.partial(jax.jit, static_argnums=0)
def _run_plain(round_fn, desc, statics, buffers):
    return _loop(round_fn, desc, statics, buffers)


def execute_plan(tables: TaskTable, round_fn: RoundFn,
                 statics: Sequence, buffers: Sequence, *,
                 fuse_rounds: bool = False,
                 donate: Optional[bool] = None) -> Tuple:
    """Execute a lowered task table.  ``statics`` are read-only family
    inputs (may be empty); ``buffers`` are the mutable state arrays,
    threaded round to round and returned.  ``round_fn`` must be a stable
    object (the megakernel factories are lru-cached) so repeated calls hit
    the jit cache."""
    desc = jnp.asarray(tables.desc)
    if fuse_rounds:
        desc = desc.reshape(1, -1, desc.shape[-1])
    if donate is None:
        donate = jax.default_backend() in ("tpu", "gpu")
    run = _run_donating if donate else _run_plain
    return run(round_fn, desc, tuple(statics), tuple(buffers))


@functools.partial(jax.jit, static_argnums=0)
def _run_one_round(round_fn, desc_r, statics, buffers):
    return round_fn(desc_r, statics, buffers)


def measure_round_times(tables: TaskTable, round_fn: RoundFn,
                        statics: Sequence, buffers: Sequence,
                        ) -> Tuple[List[float], Tuple]:
    """Execute a task table one round slab at a time, timing each launch
    (blocked on completion) — the measured per-round engine times that
    ``core.simulator.replay_round_times`` feeds back into the discrete-
    event model to validate its makespan prediction against the fused
    single-dispatch execute time (ROADMAP: simulator validation).  The
    first round is pre-run once as compile warmup (all slabs share one
    shape, so one compilation covers every round).  Returns
    ``(seconds_per_round, final_buffers)``."""
    statics = tuple(statics)
    bufs = tuple(buffers)
    desc = jnp.asarray(tables.desc)
    times: List[float] = []
    if tables.nr_rounds:
        jax.block_until_ready(
            _run_one_round(round_fn, desc[0], statics, bufs))  # warmup only
    for r in range(tables.nr_rounds):
        t0 = time.perf_counter()
        bufs = _run_one_round(round_fn, desc[r], statics, bufs)
        jax.block_until_ready(bufs)
        times.append(time.perf_counter() - t0)
    return times, bufs
