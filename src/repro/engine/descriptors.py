"""Task-table lowering: an ExecutionPlan as ragged device-resident arrays.

``lower_tables`` turns a lowered :class:`~repro.core.plan.ExecutionPlan`
into a :class:`TaskTable` — a flat CSR descriptor array over rounds and
write-colored sub-phases — by asking the same ``BatchSpec`` registry that
drives the host round executor for each task's *device* encoding
(``BatchSpec.encode``).  QR, Barnes-Hut and the pipeline F/B/U synthesizer
all lower through this one path; what differs per family is only the
encoder, the row-access map that drives the write coloring, and the
megakernel that interprets the rows (``repro.engine.megakernel``).  The
``engine`` entry of the execution backend registry (``core/backends.py``,
DESIGN.md §Backends) drives this lowering for any family whose registry
carries encoders plus ``EngineHooks``.  Layout and invariants: DESIGN.md
§Engine ("Ragged tables & grid walk").

A descriptor row is ``[engine_type, arg0, ..., arg{A-1}]`` (int32).  One
*task* may encode to several rows (Barnes-Hut tasks expand into their
direct-interaction work items); rows inherit the task's round, so every
round's row slice stays conflict-free — rows of one round belong to tasks
whose locked resource subtrees are disjoint (property-tested in
``tests/test_engine_properties.py``).  Rows carry whatever per-item
scalars the family's round function needs beyond identity — the serving
tier's decode rows are ``[ENG_DECODE, slot, pos]`` so the per-slot
page-walk bound rides the descriptor into the paged-attention kernel
(DESIGN.md §Serving) instead of round-tripping through host state.  Row order within a round mirrors
``ExecutionPlan.execute``: typed batches in ascending type order, tasks in
batch order — so the engine's observable sequencing matches the host
rounds mode.  Virtual tasks encode to nothing; empty rounds lower to a
zero-length CSR slice, never a synthetic no-op row.

The table is *ragged*: rounds index the flat row array through
``round_offsets`` and each round is further split into contiguous
sub-phases (``phase_offsets``, ``round_phase_ptr``) by the write-coloring
pass (:func:`repro.core.plan.color_phases` over the family's
``row_access`` map), such that no two items of one phase read or write a
common state row.  Phases are what the megakernel's grid dimension walks —
items of a phase may execute in any order or in parallel, phases run in
order.  There are NO padding rows anywhere (``stats["pad_fraction"]`` is
identically 0; CI asserts it).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import FLAG_VIRTUAL, QSched
from repro.core.plan import BatchSpec, ExecutionPlan, color_phases
from repro.obs import trace as _trace

# row -> (reads, writes): hashable state-row keys a descriptor row loads
# from / stores to, in a family-defined keyspace.  Drives the write
# coloring; the per-family maps live next to the row layouts in
# ``repro.engine.megakernel``.
RowAccess = Callable[[Tuple[int, ...]], Tuple[Sequence, Sequence]]


@dataclass(frozen=True)
class TaskTable:
    """Ragged, device-ready descriptor tables for one lowered plan.

    ``desc[q]`` is flat row ``q``: ``[etype, args...]`` (unused trailing
    arg columns are zero); ``tids[q]`` is the owning task id — host-side
    provenance for tests, stats and per-item cost replay, never shipped to
    the kernel.  ``round_offsets`` (CSR over rounds) and ``phase_offsets``
    (CSR over write-colored sub-phases, plan-wide) both index ``desc``;
    ``round_phase_ptr[r]:round_phase_ptr[r+1]`` are round ``r``'s phase
    ids, so its phase boundaries are
    ``phase_offsets[round_phase_ptr[r] : round_phase_ptr[r+1] + 1]``.
    """
    desc: np.ndarray             # (nr_items, 1 + arg_width) int32
    tids: np.ndarray             # (nr_items,) int32
    round_offsets: np.ndarray    # (R + 1,) int64, CSR over rounds
    phase_offsets: np.ndarray    # (P + 1,) int64, CSR over sub-phases
    round_phase_ptr: np.ndarray  # (R + 1,) int64, round -> phase id range
    arg_width: int
    nr_tasks: int
    structural_hash: str
    stats: Dict[str, Any] = field(default_factory=dict)

    @property
    def nr_rounds(self) -> int:
        return self.round_offsets.shape[0] - 1

    @property
    def nr_phases(self) -> int:
        return self.phase_offsets.shape[0] - 1

    @property
    def nr_items(self) -> int:
        return int(self.round_offsets[-1])

    @property
    def round_lengths(self) -> np.ndarray:
        return np.diff(self.round_offsets)

    def round_rows(self, r: int) -> np.ndarray:
        o0, o1 = int(self.round_offsets[r]), int(self.round_offsets[r + 1])
        return self.desc[o0:o1]

    def round_tids(self, r: int) -> List[int]:
        o0, o1 = int(self.round_offsets[r]), int(self.round_offsets[r + 1])
        return self.tids[o0:o1].tolist()

    def round_phases(self, r: int) -> np.ndarray:
        """Round ``r``'s phase boundaries as offsets into the flat row
        array (``[round_offsets[r], ..., round_offsets[r+1]]``; length 1
        for an empty round)."""
        p0, p1 = int(self.round_phase_ptr[r]), int(self.round_phase_ptr[r + 1])
        if p0 == p1:
            return self.round_offsets[r:r + 1].copy()
        return self.phase_offsets[p0:p1 + 1]


def lower_tables(plan: ExecutionPlan, sched: QSched,
                 registry: Mapping[int, BatchSpec], *,
                 arg_width: int,
                 row_access: Optional[RowAccess] = None) -> TaskTable:
    """Lower a plan's rounds into a ragged :class:`TaskTable` via the
    registry's ``encode`` hooks, write-coloring each round's rows into
    sub-phases with ``row_access`` (no ``row_access``: one phase per
    non-empty round — only valid when the caller guarantees a round's rows
    never touch a common state row, or the walk stays sequential).  Raises
    ``KeyError`` when a non-virtual task type has no spec or no encoder,
    mirroring ``ExecutionPlan.execute``."""
    plan.check_compatible(sched)
    flags = sched._tflags
    datas = sched._tdata
    all_rows: List[Tuple[int, ...]] = []
    all_tids: List[int] = []
    round_offsets = np.zeros(plan.nr_rounds + 1, dtype=np.int64)
    phase_offsets: List[int] = [0]
    round_phase_ptr = np.zeros(plan.nr_rounds + 1, dtype=np.int64)
    tables_span = _trace.span("engine.lower_tables", tasks=plan.nr_tasks,
                              rounds=plan.nr_rounds)
    with tables_span:
        for r, rnd in enumerate(plan.rounds):
            rows: List[Tuple[int, ...]] = []
            rtids: List[int] = []
            with _trace.span("engine.encode", round=r):
                for tb in rnd.batches:
                    real = [t for t in tb.tids
                            if not flags[t] & FLAG_VIRTUAL]
                    if not real:
                        continue
                    spec = registry.get(tb.ttype)
                    if spec is None:
                        raise KeyError(
                            f"no BatchSpec registered for task type "
                            f"{tb.ttype}")
                    if spec.encode is None:
                        raise KeyError(
                            f"BatchSpec for task type {tb.ttype} has no "
                            f"engine encoder (BatchSpec.encode)")
                    for tid in real:
                        for row in spec.encode(tid, datas[tid]):
                            row = tuple(int(v) for v in row)
                            if len(row) > 1 + arg_width:
                                raise ValueError(
                                    f"encoder for type {tb.ttype} emitted "
                                    f"{len(row)} columns, table holds "
                                    f"{1 + arg_width}")
                            rows.append(row)
                            rtids.append(tid)
            base = len(all_rows)
            if rows:
                if row_access is None:
                    bounds = [0, len(rows)]
                else:
                    bounds = color_phases([row_access(row) for row in rows])
                phase_offsets.extend(base + b for b in bounds[1:])
            # empty rounds contribute zero phases + a zero-length CSR slice
            all_rows.extend(rows)
            all_tids.extend(rtids)
            round_offsets[r + 1] = len(all_rows)
            round_phase_ptr[r + 1] = len(phase_offsets) - 1
        tables_span.args["items"] = len(all_rows)
        tables_span.args["phases"] = len(phase_offsets) - 1

    nr_items = len(all_rows)
    desc = np.zeros((nr_items, 1 + arg_width), dtype=np.int32)
    for q, row in enumerate(all_rows):
        desc[q, :len(row)] = row
    tids = np.asarray(all_tids, dtype=np.int32)
    phase_off = np.asarray(phase_offsets, dtype=np.int64)
    lengths = np.diff(round_offsets)
    width = int(lengths.max()) if lengths.size else 0
    nr_phases = phase_off.shape[0] - 1
    phase_lengths = np.diff(phase_off)
    # measured, not asserted-by-construction: rows allocated in the flat
    # array beyond what the round CSR references are pad/filler work (CI
    # gates pad_fraction == 0, so a layout change that reintroduces
    # filler rows fails the gate instead of silently inflating the walk)
    pad_rows = desc.shape[0] - int(round_offsets[-1])
    return TaskTable(
        desc=desc, tids=tids, round_offsets=round_offsets,
        phase_offsets=phase_off, round_phase_ptr=round_phase_ptr,
        arg_width=arg_width, nr_tasks=plan.nr_tasks,
        structural_hash=plan.structural_hash,
        stats={"rounds": plan.nr_rounds, "phases": nr_phases,
               "items": nr_items, "width": width,
               "max_phase_len": int(phase_lengths.max())
               if phase_lengths.size else 0,
               # the dense layout this table replaces padded every round
               # to the plan-wide max width; the ragged walk does zero
               # pad work — benchmarks report the ratio as walk_reduction
               "padded_rows": plan.nr_rounds * width,
               "pad_rows": pad_rows,
               "pad_fraction": pad_rows / max(desc.shape[0], 1)})


def count_host_dispatches(plan: ExecutionPlan, sched: QSched,
                          registry: Mapping[int, BatchSpec]) -> int:
    """Host kernel dispatches the per-round BatchSpec path performs for
    this plan: one per batched group, one per ``run_one`` task.  The engine
    replaces all of them with a single jitted call — this is the
    denominator of the dispatch-reduction figure in
    ``benchmarks/engine_dispatch.py``."""
    flags = sched._tflags
    n = 0
    for rnd in plan.rounds:
        for tb in rnd.batches:
            real = [t for t in tb.tids if not flags[t] & FLAG_VIRTUAL]
            if not real:
                continue
            spec = registry.get(tb.ttype)
            if (spec is not None and spec.run_batch is not None
                    and len(real) >= spec.min_batch):
                n += 1
            else:
                n += len(real)
    return n
