"""Task-table lowering: an ExecutionPlan as dense device-resident arrays.

``lower_tables`` turns a lowered :class:`~repro.core.plan.ExecutionPlan`
into a :class:`TaskTable` — per-round, padded integer descriptor slabs plus
round offsets/lengths — by asking the same ``BatchSpec`` registry that
drives the host round executor for each task's *device* encoding
(``BatchSpec.encode``).  QR, Barnes-Hut and the pipeline F/B/U synthesizer
all lower through this one path; what differs per family is only the
encoder and the megakernel that interprets the rows
(``repro.engine.megakernel``).  The ``engine`` entry of the execution
backend registry (``core/backends.py``, DESIGN.md §Backends) drives this
lowering for any family whose registry carries encoders plus
``EngineHooks``.  Layout and invariants: DESIGN.md §Engine.

A descriptor row is ``[engine_type, arg0, ..., arg{A-1}]`` (int32).  One
*task* may encode to several rows (Barnes-Hut tasks expand into their
direct-interaction work items); rows inherit the task's round, so every
slab stays conflict-free — rows of one round belong to tasks whose locked
resource subtrees are disjoint (property-tested in
``tests/test_engine_properties.py``).  Row order within a round mirrors
``ExecutionPlan.execute``: typed batches in ascending type order, tasks in
batch order — so the engine's in-round sequencing matches the host rounds
mode exactly.  Virtual tasks encode to nothing.  Slabs are padded to the
plan-wide maximum width with ``pad_type`` rows (the megakernel's no-op
branch).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Tuple

import numpy as np

from repro.core.graph import FLAG_VIRTUAL, QSched
from repro.core.plan import BatchSpec, ExecutionPlan


@dataclass(frozen=True)
class TaskTable:
    """Dense, device-ready descriptor tables for one lowered plan.

    ``desc[r, q]`` is row ``q`` of round ``r``: ``[etype, args...]``;
    ``tids[r, q]`` is the owning task id (-1 for padding) — host-side
    provenance for tests and stats, never shipped to the kernel.
    ``lengths[r]`` counts real rows; ``offsets`` are the flat row offsets
    of each round within the plan (``offsets[-1] == nr_items``).
    """
    desc: np.ndarray           # (R, W, 1 + arg_width) int32
    tids: np.ndarray           # (R, W) int32, -1 padded
    lengths: np.ndarray        # (R,) int32
    offsets: np.ndarray        # (R + 1,) int64
    arg_width: int
    pad_type: int
    nr_tasks: int
    structural_hash: str
    stats: Dict[str, Any] = field(default_factory=dict)

    @property
    def nr_rounds(self) -> int:
        return self.desc.shape[0]

    @property
    def width(self) -> int:
        return self.desc.shape[1]

    @property
    def nr_items(self) -> int:
        return int(self.offsets[-1])

    def round_tids(self, r: int) -> List[int]:
        row = self.tids[r]
        return row[row >= 0].tolist()


def lower_tables(plan: ExecutionPlan, sched: QSched,
                 registry: Mapping[int, BatchSpec], *,
                 arg_width: int, pad_type: int) -> TaskTable:
    """Lower a plan's rounds into a :class:`TaskTable` via the registry's
    ``encode`` hooks.  Raises ``KeyError`` when a non-virtual task type has
    no spec or no encoder, mirroring ``ExecutionPlan.execute``."""
    plan.check_compatible(sched)
    flags = sched._tflags
    datas = sched._tdata
    per_round_rows: List[List[Tuple[int, ...]]] = []
    per_round_tids: List[List[int]] = []
    for rnd in plan.rounds:
        rows: List[Tuple[int, ...]] = []
        rtids: List[int] = []
        for tb in rnd.batches:
            real = [t for t in tb.tids if not flags[t] & FLAG_VIRTUAL]
            if not real:
                continue
            spec = registry.get(tb.ttype)
            if spec is None:
                raise KeyError(
                    f"no BatchSpec registered for task type {tb.ttype}")
            if spec.encode is None:
                raise KeyError(
                    f"BatchSpec for task type {tb.ttype} has no engine "
                    f"encoder (BatchSpec.encode)")
            for tid in real:
                for row in spec.encode(tid, datas[tid]):
                    row = tuple(int(v) for v in row)
                    if len(row) > 1 + arg_width:
                        raise ValueError(
                            f"encoder for type {tb.ttype} emitted {len(row)}"
                            f" columns, table holds {1 + arg_width}")
                    rows.append(row)
                    rtids.append(tid)
        per_round_rows.append(rows)
        per_round_tids.append(rtids)

    # an empty plan lowers to a genuinely 0-round table, so the
    # nr_rounds == plan.nr_rounds invariant holds for every input
    nr_rounds = len(per_round_rows)
    width = max((len(r) for r in per_round_rows), default=0) or 1
    desc = np.zeros((nr_rounds, width, 1 + arg_width), dtype=np.int32)
    desc[:, :, 0] = pad_type
    tids = np.full((nr_rounds, width), -1, dtype=np.int32)
    lengths = np.zeros(nr_rounds, dtype=np.int32)
    for r, (rows, rtids) in enumerate(zip(per_round_rows, per_round_tids)):
        lengths[r] = len(rows)
        for q, row in enumerate(rows):
            desc[r, q, :len(row)] = row
        if rtids:
            tids[r, :len(rtids)] = rtids
    offsets = np.zeros(nr_rounds + 1, dtype=np.int64)
    np.cumsum(lengths, out=offsets[1:])
    nr_items = int(offsets[-1])
    pad_rows = nr_rounds * width - nr_items
    return TaskTable(
        desc=desc, tids=tids, lengths=lengths, offsets=offsets,
        arg_width=arg_width, pad_type=pad_type, nr_tasks=plan.nr_tasks,
        structural_hash=plan.structural_hash,
        stats={"rounds": nr_rounds, "width": width, "items": nr_items,
               "pad_rows": pad_rows,
               "pad_fraction": pad_rows / max(nr_rounds * width, 1)})


def count_host_dispatches(plan: ExecutionPlan, sched: QSched,
                          registry: Mapping[int, BatchSpec]) -> int:
    """Host kernel dispatches the per-round BatchSpec path performs for
    this plan: one per batched group, one per ``run_one`` task.  The engine
    replaces all of them with a single jitted call — this is the
    denominator of the dispatch-reduction figure in
    ``benchmarks/engine_dispatch.py``."""
    flags = sched._tflags
    n = 0
    for rnd in plan.rounds:
        for tb in rnd.batches:
            real = [t for t in tb.tids if not flags[t] & FLAG_VIRTUAL]
            if not real:
                continue
            spec = registry.get(tb.ttype)
            if (spec is not None and spec.run_batch is not None
                    and len(real) >= spec.min_batch):
                n += 1
            else:
                n += len(real)
    return n
