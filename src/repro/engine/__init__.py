"""repro.engine: device-resident ExecutionPlan execution.

Lower a prepared plan into ragged CSR task tables with write-colored
sub-phases (``descriptors``), execute them through one grid-parallel
type-branching Pallas megakernel per task family (``megakernel``), and
drive the whole plan as a single jitted dispatch with donated buffers
(``runner``) — one host dispatch per plan instead of one per task/batch
per round, and zero padded walk work.  DESIGN.md §Engine.
"""

from .descriptors import TaskTable, count_host_dispatches, lower_tables
from .megakernel import (BH_ARG_WIDTH, BH_COM_INNER, BH_COM_LEAF,
                         BH_MAX_CHILDREN, BH_NOOP, BH_PC, BH_PP, BH_SELF,
                         DEFAULT_BLOCK_ITEMS,
                         PIPE_ARG_WIDTH, PIPE_B, PIPE_F, PIPE_NOOP, PIPE_U,
                         QR_ARG_WIDTH, QR_GEQRF, QR_LARFT, QR_NOOP,
                         QR_SSRFT, QR_TSQRF, bh_round_fn, bh_row_access,
                         pipe_round_fn, pipe_row_access, qr_round_fn,
                         qr_row_access)
from .runner import (ENGINE_DISPATCHES_PER_PLAN, RoundTimings, execute_plan,
                     measure_round_times)

__all__ = [
    "TaskTable", "lower_tables", "count_host_dispatches",
    "qr_round_fn", "bh_round_fn", "pipe_round_fn", "execute_plan",
    "measure_round_times", "RoundTimings", "ENGINE_DISPATCHES_PER_PLAN",
    "qr_row_access", "bh_row_access", "pipe_row_access",
    "DEFAULT_BLOCK_ITEMS",
    "QR_GEQRF", "QR_LARFT", "QR_TSQRF", "QR_SSRFT", "QR_NOOP",
    "QR_ARG_WIDTH",
    "BH_COM_LEAF", "BH_COM_INNER", "BH_SELF", "BH_PP", "BH_PC", "BH_NOOP",
    "BH_ARG_WIDTH", "BH_MAX_CHILDREN",
    "PIPE_F", "PIPE_B", "PIPE_U", "PIPE_NOOP", "PIPE_ARG_WIDTH",
]
