from .steps import make_prefill_step, make_serve_step, make_train_step

__all__ = ["make_train_step", "make_serve_step", "make_prefill_step"]
