"""Step builders: train / prefill / decode as pure jittable functions.

``make_train_step`` closes over the optimizer; the returned function has
signature ``(params, opt_state, batch) -> (params, opt_state, metrics)`` and
is what the dry-run lowers with full-size ShapeDtypeStructs.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm, serving
from repro.optim import (clip_by_global_norm, cosine_schedule,
                         default_optimizer_for, make_optimizer)

Pytree = Any


def make_train_step(cfg, optimizer: str = "auto", lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000,
                    grad_clip: float = 1.0):
    """Returns (train_step, opt_init)."""
    if optimizer == "auto":
        optimizer = default_optimizer_for(cfg)
    sched = cosine_schedule(lr, warmup, total_steps)
    opt_init, opt_update = make_optimizer(optimizer, sched)

    def train_step(params: Pytree, opt_state, batch: Dict[str, jnp.ndarray]):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, cfg, batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt_update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step, opt_init


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        extra = {k: v for k, v in batch.items() if k != "tokens"}
        logits, cache, pos = serving.prefill(params, cfg, batch["tokens"],
                                             extra=extra)
        return logits, cache, pos

    return prefill_step


def make_serve_step(cfg):
    """One-token decode; the cache argument is donated by callers that jit
    with ``donate_argnums=(1,)``."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = serving.decode_step(params, cfg, cache, tokens, pos)
        return logits, cache

    return serve_step


def init_train_state(cfg, key, optimizer: str = "auto"):
    """Host-side init (small configs); the dry-run uses jax.eval_shape over
    this instead."""
    if optimizer == "auto":
        optimizer = default_optimizer_for(cfg)
    opt_init, _ = make_optimizer(optimizer, 1e-4)
    params = lm.init_params(key, cfg)
    opt_state = opt_init(params)
    return params, opt_state
