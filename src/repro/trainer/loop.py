"""Training loop with checkpoint/restart fault tolerance.

``run_training`` is restartable: given the same ``workdir`` it resumes from
the latest checkpoint and — because the data pipeline is a pure function of
the step counter — continues bit-identically (tested with a mid-run kill in
tests/test_traincore.py).  ``fail_at_step`` injects a hard failure for that
test.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticTokens
from repro.models import lm
from repro.obs import trace as _trace
from repro.optim import make_optimizer
from .steps import make_train_step


class InjectedFailure(RuntimeError):
    pass


def run_training(cfg, workdir: str, steps: int, seq_len: int = 128,
                 global_batch: int = 8, lr: float = 3e-4,
                 optimizer: str = "auto", ckpt_every: int = 50,
                 fail_at_step: Optional[int] = None, seed: int = 0,
                 log_every: int = 10, async_ckpt: bool = False,
                 log_fn: Callable[[str], None] = print):
    """Returns (params, opt_state, history list of (step, loss))."""
    train_step, opt_init = make_train_step(
        cfg, optimizer=optimizer, lr=lr, total_steps=max(steps, 1))
    jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    data = SyntheticTokens(cfg.vocab, seq_len, global_batch, seed=seed)
    mgr = CheckpointManager(f"{workdir}/ckpt", keep=3, async_save=async_ckpt)

    key = jax.random.PRNGKey(seed)
    params = lm.init_params(key, cfg)
    opt_state = opt_init(params)
    start = 0
    latest = mgr.latest()
    if latest is not None:
        state = mgr.restore(latest, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = latest
        log_fn(f"[resume] restored step {latest}")

    history = []
    t0 = time.time()
    for step in range(start, steps):
        if fail_at_step is not None and step == fail_at_step:
            raise InjectedFailure(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        _extend_modality(batch, cfg)
        # the float() below already syncs on the result, so the span
        # covers real step time even without an explicit block
        with _trace.span("train.step", step=step) as sp:
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            sp.args["loss"] = loss
        history.append((step, loss))
        if not np.isfinite(loss):
            raise FloatingPointError(f"non-finite loss at step {step}")
        if step % log_every == 0:
            dt = time.time() - t0
            log_fn(f"step {step:5d} loss {loss:.4f} "
                   f"({dt / max(step - start + 1, 1):.2f}s/step)")
        if ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    mgr.wait()
    if ckpt_every:
        mgr.save(steps, {"params": params, "opt": opt_state})
        mgr.wait()
    return params, opt_state, history


def _extend_modality(batch: Dict, cfg) -> None:
    b = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["vis_embeds"] = jnp.zeros((b, cfg.n_vis_tokens, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
