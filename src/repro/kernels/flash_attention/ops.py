"""Public op: model-layout wrapper for the flash-attention kernel.

Accepts (B, S, H, hd) like the model's sdpa paths, pads S to block
multiples with masked-out rows, flattens (B,H) into the kernel grid."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention_bshd(q, k, v, causal: bool = True,
                         block_q: int = 128, block_k: int = 128):
    """q,k,v: (B, S, H, hd) → (B, S, H, hd)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)

    qf, kf, vf = to_bh(q), to_bh(k), to_bh(v)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        # padded keys must never win the softmax: zero K with a causal row
        # index beyond every query works for causal; for non-causal we add
        # an explicit -inf bias by padding K with +inf-distance rows, which
        # the kernel's masking cannot see — so fall back to exact sizes.
        assert causal or pk == 0, "non-causal requires Sk % block_k == 0"
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    o = kernel.flash_attention(qf, kf, vf, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())
    o = o[:, :sq]
    return o.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


def attention_ref_bshd(q, k, v, causal: bool = True):
    b, sq, h, hd = q.shape

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)

    o = ref.attention_ref(to_bh(q), to_bh(k), to_bh(v), causal=causal)
    return o.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
