"""Oracle for the flash-attention kernel: plain softmax attention over
(BH, S, hd) with optional causal mask, fp32 softmax."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, hd); k,v: (BH, Sk, hd)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
