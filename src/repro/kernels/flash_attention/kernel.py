"""Flash-attention Pallas kernel (TPU target).

Grid: (batch·heads, Sq/BLOCK_Q).  Each program holds one (BLOCK_Q, hd)
query tile plus the full (Sk, hd) K/V for its batch-head in VMEM (Sk·hd·2·
2 B ≈ 2 MiB at Sk=4096, hd=128 bf16 — comfortably inside the ~16 MiB VMEM
budget; longer sequences tile Sk via the same BlockSpec pattern), and runs
the online-softmax recurrence over BLOCK_K slices:

    m ← max(m, rowmax(s));  l ← l·α + rowsum(p);  acc ← acc·α + p·V

MXU work is the two (BLOCK_Q × BLOCK_K × hd) matmuls per slice; the causal
variant skips fully-masked K slices' contribution via masking (the
structural flop count is what the roofline uses — the paper-level win is
never materialising S² scores in HBM).

Validated against ref.attention_ref in interpret mode
(tests/test_kernels_flash.py), and against the model's chunked-jnp
attention path (same math)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, sk: int,
               block_k: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                 # (BQ, hd)
    bq = q.shape[0]
    nk = sk // block_k

    def body(j, carry):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[0], (j * block_k, 0), (block_k, k_ref.shape[2])
        ).astype(jnp.float32)                        # (BK, hd)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0], (j * block_k, 0), (block_k, v_ref.shape[2])
        ).astype(jnp.float32)
        s = (q @ k_blk.T) * scale                    # (BQ, BK) on the MXU
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, 1), 0)
            cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ v_blk
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc0 = jnp.zeros((bq, v_ref.shape[2]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = BLOCK_Q,
                    block_k: int = BLOCK_K, interpret: bool = True):
    """q: (BH, Sq, hd); k, v: (BH, Sk, hd).  Sq % block_q == 0 and
    Sk % block_k == 0 (ops.py pads)."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0
    scale = hd ** -0.5
    grid = (bh, sq // block_q)
    return pl.pallas_call(
        functools.partial(_fa_kernel, causal=causal, sk=sk,
                          block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, sk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
