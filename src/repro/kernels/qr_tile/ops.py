"""Public ops for the tiled-QR kernels.

``backend`` selects between the Pallas kernel (TPU target; ``interpret``
mode executes the kernel body on CPU for validation) and the pure-jnp
reference oracle.  On a CPU runtime the default is the Pallas kernel in
interpret mode so the kernel path is always exercised.
"""

from __future__ import annotations

import jax

from . import kernel, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def geqrf(a, backend: str = "pallas"):
    if backend == "ref":
        return ref.geqrf_ref(a)
    return kernel.geqrf(a, interpret=_interpret())


def apply_qt(rv, t, c, backend: str = "pallas"):
    if backend == "ref":
        return ref.apply_qt_ref(rv, t, c)
    return kernel.apply_qt(rv, t, c, interpret=_interpret())


def tsqrf(r, a, backend: str = "pallas"):
    if backend == "ref":
        return ref.tsqrf_ref(r, a)
    return kernel.tsqrf(r, a, interpret=_interpret())


def apply_tsqt(v2, t, c1, c2, backend: str = "pallas"):
    if backend == "ref":
        return ref.apply_tsqt_ref(v2, t, c1, c2)
    return kernel.apply_tsqt(v2, t, c1, c2, interpret=_interpret())
