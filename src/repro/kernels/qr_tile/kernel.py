"""Pallas TPU kernels for the four tiled-QR operations (paper §4.1).

Each kernel operates on one (b,b) tile resident in VMEM (b=64 → 16 KiB per
buffer in fp32, far under the ~16 MiB VMEM budget; b=128 is the
MXU-aligned production tile).  The panel factorizations (geqrf, tsqrf) are
column-recurrence loops — VPU-bound rank-1 updates expressed with 2-D masks
(TPU iota must be ≥2-D) — while the *apply* kernels (larft, ssrft) are pure
matmul chains that run on the MXU; in the tiled algorithm the applies
dominate the flop count (O(N²) applies vs O(N) factorizations per level),
which is exactly why this decomposition suits the TPU.

Validated against ``ref.py`` in interpret mode (tests/test_kernels_qr.py).

The numerical bodies are exposed as pure value-level functions
(``geqrf_math`` / ``tsqrf_math`` / ``apply_qt_math`` / ``apply_tsqt_math``)
so the per-op kernels here and the fused engine megakernel
(``repro.engine.megakernel``, DESIGN.md §Engine) trace the exact same math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _iotas(b: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    return rows, cols


def _householder(alpha, sigma2, dtype):
    zero = sigma2 == 0.0
    sign = jnp.where(alpha >= 0.0, jnp.asarray(1.0, dtype),
                     jnp.asarray(-1.0, dtype))
    beta = jnp.where(zero, alpha, -sign * jnp.sqrt(alpha * alpha + sigma2))
    tau = jnp.where(zero, jnp.asarray(0.0, dtype),
                    (beta - alpha) / jnp.where(zero, jnp.asarray(1.0, dtype), beta))
    denom = alpha - beta
    inv = jnp.where(zero, jnp.asarray(0.0, dtype),
                    1.0 / jnp.where(denom == 0.0, jnp.asarray(1.0, dtype), denom))
    return beta, tau, inv


def geqrf_math(a0):
    """Value-level DGEQRF body: (b,b) tile → (RV, taus (1,b), T)."""
    b = a0.shape[0]
    dtype = a0.dtype
    rows, cols = _iotas(b)

    def body(j, carry):
        a, v_acc, taus, t = carry
        colmask = (cols == j).astype(dtype)            # (1,b)
        rowpick = (rows == j).astype(dtype)            # (b,1)
        below = (rows > j).astype(dtype)               # (b,1)
        x = jnp.sum(a * colmask, axis=1, keepdims=True)  # column j, (b,1)
        alpha = jnp.sum(x * rowpick)
        sigma2 = jnp.sum((x * below) ** 2)
        beta, tau, inv = _householder(alpha, sigma2, dtype)
        v = x * below * inv + rowpick                  # (b,1), v[j] = 1
        w = tau * (v.T @ a)                            # (1,b) MXU matvec
        w = w * (cols > j).astype(dtype)               # trailing columns only
        a = a - v @ w
        # column j: R above the diagonal, beta on it, v below it
        newcol = x * (rows < j).astype(dtype) + beta * rowpick + v * below
        a = jnp.where(cols == j, newcol, a)
        # T recurrence: u = V^T v (columns >= j of V are still zero)
        u = v_acc.T @ v                                # (b,1)
        tcol = -tau * (t @ u) + tau * rowpick
        t = jnp.where(cols == j, tcol, t)
        v_acc = jnp.where(cols == j, v, v_acc)
        taus = jnp.where(cols == j, tau, taus)
        return a, v_acc, taus, t

    z = jnp.zeros((b, b), dtype)
    a, _, taus, t = jax.lax.fori_loop(
        0, b, body, (a0, z, jnp.zeros((1, b), dtype), z))
    return a, taus, t


def _geqrf_kernel(a_ref, rv_ref, tau_ref, t_ref):
    rv, taus, t = geqrf_math(a_ref[...])
    rv_ref[...] = rv
    tau_ref[...] = taus
    t_ref[...] = t


def tsqrf_math(r0, a0):
    """Value-level DTSQRF body: (R tile, rectangular tile) → (R', V2,
    taus (1,b), T)."""
    b = r0.shape[0]
    dtype = r0.dtype
    rows, cols = _iotas(b)

    def body(j, carry):
        r, a, v2, taus, t = carry
        colmask = (cols == j).astype(dtype)
        rowpick = (rows == j).astype(dtype)            # (b,1)
        alpha = jnp.sum(r * ((rows == j) & (cols == j)).astype(dtype))
        x = jnp.sum(a * colmask, axis=1, keepdims=True)  # (b,1)
        sigma2 = jnp.sum(x * x)
        beta, tau, inv = _householder(alpha, sigma2, dtype)
        v = x * inv                                    # (b,1) bottom block
        rrow = jnp.sum(r * (rows == j).astype(dtype), axis=0, keepdims=True)
        w = rrow + v.T @ a                             # (1,b)
        r = r - tau * (rowpick @ w)                    # only row j changes
        a = a - tau * (v @ w)
        r = jnp.where((rows == j) & (cols == j), beta, r)
        a = a * (cols != j).astype(dtype)              # column j eliminated
        # T recurrence over the dense bottom blocks only
        u = v2.T @ v
        tcol = -tau * (t @ u) + tau * rowpick
        t = jnp.where(cols == j, tcol, t)
        v2 = jnp.where(cols == j, v, v2)
        taus = jnp.where(cols == j, tau, taus)
        return r, a, v2, taus, t

    z = jnp.zeros((b, b), dtype)
    r, _, v2, taus, t = jax.lax.fori_loop(
        0, b, body, (r0, a0, z, jnp.zeros((1, b), dtype), z))
    return r, v2, taus, t


def _tsqrf_kernel(r_ref, a_ref, r_out_ref, v2_ref, tau_ref, t_ref):
    r, v2, taus, t = tsqrf_math(r_ref[...], a_ref[...])
    r_out_ref[...] = r
    v2_ref[...] = v2
    tau_ref[...] = taus
    t_ref[...] = t


def apply_qt_math(rv, t, c):
    """Value-level DLARFT body: C ← (I - V T Vᵀ)ᵀ C with V packed below
    the diagonal of ``rv``."""
    b = rv.shape[0]
    dtype = rv.dtype
    rows, cols = _iotas(b)
    v = jnp.where(rows > cols, rv, jnp.zeros((b, b), dtype))
    v = v + (rows == cols).astype(dtype)
    return c - v @ (t.T @ (v.T @ c))


def _apply_qt_kernel(rv_ref, t_ref, c_ref, out_ref):
    out_ref[...] = apply_qt_math(rv_ref[...], t_ref[...], c_ref[...])


def apply_tsqt_math(v2, t, c1, c2):
    """Value-level DSSRFT body: apply the (I ; V2) block reflector to the
    stacked (C1 ; C2) pair."""
    w = t.T @ (c1 + v2.T @ c2)
    return c1 - w, c2 - v2 @ w


def _apply_tsqt_kernel(v2_ref, t_ref, c1_ref, c2_ref, o1_ref, o2_ref):
    o1, o2 = apply_tsqt_math(v2_ref[...], t_ref[...], c1_ref[...], c2_ref[...])
    o1_ref[...] = o1
    o2_ref[...] = o2


def _tile_spec(shape):
    """Whole-tile VMEM block (the tile is the unit of work; the task
    scheduler, not the grid, provides the outer parallelism)."""
    return pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))


@functools.partial(jax.jit, static_argnames=("interpret",))
def geqrf(a, *, interpret: bool = True):
    b = a.shape[-1]
    d = a.dtype
    rv, tau, t = pl.pallas_call(
        _geqrf_kernel,
        grid=(),
        in_specs=[_tile_spec((b, b))],
        out_specs=(_tile_spec((b, b)), _tile_spec((1, b)), _tile_spec((b, b))),
        out_shape=(jax.ShapeDtypeStruct((b, b), d),
                   jax.ShapeDtypeStruct((1, b), d),
                   jax.ShapeDtypeStruct((b, b), d)),
        interpret=interpret,
    )(a)
    return rv, tau[0], t


@functools.partial(jax.jit, static_argnames=("interpret",))
def tsqrf(r, a, *, interpret: bool = True):
    b = a.shape[-1]
    d = a.dtype
    r1, v2, tau, t = pl.pallas_call(
        _tsqrf_kernel,
        grid=(),
        in_specs=[_tile_spec((b, b))] * 2,
        out_specs=(_tile_spec((b, b)), _tile_spec((b, b)),
                   _tile_spec((1, b)), _tile_spec((b, b))),
        out_shape=(jax.ShapeDtypeStruct((b, b), d),
                   jax.ShapeDtypeStruct((b, b), d),
                   jax.ShapeDtypeStruct((1, b), d),
                   jax.ShapeDtypeStruct((b, b), d)),
        interpret=interpret,
    )(r, a)
    return r1, v2, tau[0], t


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_qt(rv, t, c, *, interpret: bool = True):
    b = c.shape[-1]
    return pl.pallas_call(
        _apply_qt_kernel,
        grid=(),
        in_specs=[_tile_spec((b, b))] * 3,
        out_specs=_tile_spec((b, b)),
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=interpret,
    )(rv, t, c)


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_tsqt(v2, t, c1, c2, *, interpret: bool = True):
    b = c1.shape[-1]
    return pl.pallas_call(
        _apply_tsqt_kernel,
        grid=(),
        in_specs=[_tile_spec((b, b))] * 4,
        out_specs=(_tile_spec((b, b)), _tile_spec((b, b))),
        out_shape=(jax.ShapeDtypeStruct(c1.shape, c1.dtype),
                   jax.ShapeDtypeStruct(c2.shape, c2.dtype)),
        interpret=interpret,
    )(v2, t, c1, c2)
