"""Pure-jnp oracles for the four tiled-QR kernels (Buttari et al. 2009,
paper §4.1).  Deliberately written as straightforward column-by-column
Householder loops — the Pallas kernels are validated against these.

Conventions (LAPACK compact-WY):
  * ``geqrf``:  A (b,b) -> RV (R in upper triangle incl. diag, Householder
    vectors V in strict lower triangle, unit diagonal implicit), tau (b,),
    T (b,b upper triangular) with  Q = I - V @ T @ V.T.
  * ``apply_qt``: C <- Q^T C = C - V @ T.T @ (V.T @ C).
  * ``tsqrf``: QR of the stacked (2b,b) [R; A] with R upper triangular.
    Householder vectors are [e_j; v2_j]: the top block is the identity, so
    only the dense bottom block V2 (b,b) is stored.  Returns (R', V2, tau,
    T).
  * ``apply_tsqt``: [C1; C2] <- Q^T [C1; C2]:
        W  = T.T @ (C1 + V2.T @ C2)
        C1 <- C1 - W ;  C2 <- C2 - V2 @ W.
"""

from __future__ import annotations

import jax.numpy as jnp


def _householder(alpha, sigma2):
    """Scalar Householder quantities for pivot ``alpha`` and below-pivot
    squared norm ``sigma2``.  Returns (beta, tau, inv_denom) with the
    LAPACK convention H = I - tau * v v^T, v[pivot] = 1."""
    zero = sigma2 == 0.0
    sign = jnp.where(alpha >= 0.0, 1.0, -1.0)
    beta = jnp.where(zero, alpha, -sign * jnp.sqrt(alpha * alpha + sigma2))
    tau = jnp.where(zero, 0.0, (beta - alpha) / jnp.where(zero, 1.0, beta))
    denom = alpha - beta
    inv = jnp.where(zero, 0.0, 1.0 / jnp.where(denom == 0.0, 1.0, denom))
    return beta, tau, inv


def geqrf_ref(a: jnp.ndarray):
    """Householder QR of one (b,b) tile."""
    b = a.shape[0]
    assert a.shape == (b, b)
    taus = []
    for j in range(b):
        x = a[:, j]
        alpha = x[j]
        below = jnp.arange(b) > j
        sigma2 = jnp.sum(jnp.where(below, x, 0.0) ** 2)
        beta, tau, inv = _householder(alpha, sigma2)
        v = jnp.where(below, x * inv, 0.0).at[j].set(1.0)
        w = tau * (v @ a)          # (b,)
        # only trailing columns are updated; earlier columns hold stored V
        w = jnp.where(jnp.arange(b) > j, w, 0.0)
        a = a - jnp.outer(v, w)
        # store R entry and V below the diagonal (LAPACK layout)
        a = a.at[j, j].set(beta)
        a = a.at[:, j].set(jnp.where(below, v, a[:, j]))
        taus.append(tau)
    tau = jnp.stack(taus)
    rv = a
    t = _build_t(jnp.tril(rv, -1) + jnp.eye(b, dtype=rv.dtype), tau)
    return rv, tau, t


def _build_t(v: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Compact-WY T factor:  T[:j,j] = -tau_j * T[:j,:j] @ (V[:, :j]^T v_j),
    T[j,j] = tau_j."""
    b = v.shape[1]
    t = jnp.zeros((b, b), dtype=v.dtype)
    for j in range(b):
        vj = v[:, j]
        u = v.T @ vj                      # (b,)
        u = jnp.where(jnp.arange(b) < j, u, 0.0)
        col = -tau[j] * (t @ u)
        col = col.at[j].set(tau[j])
        t = t.at[:, j].set(col)
    return t


def apply_qt_ref(rv: jnp.ndarray, t: jnp.ndarray, c: jnp.ndarray):
    """C <- Q^T C with Q = I - V T V^T from ``geqrf_ref``."""
    b = rv.shape[0]
    v = jnp.tril(rv, -1) + jnp.eye(b, dtype=rv.dtype)
    return c - v @ (t.T @ (v.T @ c))


def tsqrf_ref(r: jnp.ndarray, a: jnp.ndarray):
    """QR of [R; A] (triangle-on-top-of-square).  Updates R in place,
    returns (R', V2, tau, T)."""
    b = r.shape[0]
    assert a.shape == (b, b)
    v2 = jnp.zeros((b, b), dtype=a.dtype)
    taus = []
    for j in range(b):
        alpha = r[j, j]
        x = a[:, j]
        sigma2 = jnp.sum(x * x)
        beta, tau, inv = _householder(alpha, sigma2)
        v = x * inv                      # bottom block of the reflector
        # w_m = R[j,m] + v^T A[:,m]  for every column m
        w = r[j, :] + v @ a
        r = r.at[j, :].add(-tau * w)
        a = a - tau * jnp.outer(v, w)
        r = r.at[j, j].set(beta)
        a = a.at[:, j].set(jnp.zeros(b, dtype=a.dtype))
        v2 = v2.at[:, j].set(v)
        taus.append(tau)
    tau = jnp.stack(taus)
    t = _build_t(v2, tau)  # top identity blocks contribute nothing (e_i^T e_j = 0, i<j)
    return r, v2, tau, t


def apply_tsqt_ref(v2: jnp.ndarray, t: jnp.ndarray, c1: jnp.ndarray,
                   c2: jnp.ndarray):
    """[C1; C2] <- Q^T [C1; C2] for the TS reflectors of ``tsqrf_ref``."""
    w = t.T @ (c1 + v2.T @ c2)
    return c1 - w, c2 - v2 @ w
