"""Pallas paged-attention decode kernels: the in-kernel page-table walk.

The serving tier's decode used to round-trip the block pool through XLA —
gather every active slot's pages into a contiguous ``(L, bs, max_seq, …)``
cache, run the full-window attention, scatter one cell back.  These
kernels run the same math *in place* over the pool leaves:

* grid = ``(bs,)`` — one program per active slot;
* the slot's page-table row ``(max_pages,)`` and its position are
  **scalar-prefetched** (``pltpu.PrefetchScalarGridSpec``, the same idiom
  as the engine's ragged grid walk in ``engine/megakernel.py``), so SMEM
  integers drive every page load;
* the program first writes the new token's K/V into its single
  ``(page, offset)`` cell through the **aliased** pool output refs, then
  walks pages ``0 .. pos // page_size`` with a flash-attention-style
  online softmax (running max / normalizer / accumulator with correction
  factors, the ``layers.sdpa_chunked`` recurrence) — work bounded by the
  ``ceil((pos+1)/page_size)`` pages the slot actually occupies, never by
  ``max_seq``;
* positions beyond ``pos`` inside the last page are masked ``-inf``, so
  stale contents of reused pages are unreadable by construction (the
  block-pool safety contract, property-tested in
  ``tests/test_paged_properties.py``).

Visibility/aliasing contract (same as the engine megakernels): the pool
leaves are whole-array resident blocks with constant index maps, aliased
input→output; program 0 copies input→output refs and every later program
loads/stores through the output refs.  Admission guarantees distinct slots
own disjoint page sets, so the per-slot programs of one launch touch
pairwise-disjoint pool rows — the grid is safe to execute in any order,
exactly the write-coloring argument that makes a decode round one phase.

Two flavors share the walk structure:

* ``_gqa_kernel`` — dense/GQA: K/V pools ``(P, ps, Hkv, hd)``, KV heads
  repeated to ``H`` in-register, scores/context per head;
* ``_mla_kernel`` — DeepSeek MLA, weight-absorbed: pools hold the
  compressed latent ``(P, ps, lat)`` plus the shared RoPE key
  ``(P, ps, rope)``; scores are ``q_eff·c_kv + q_rope·k_rope`` and the
  context stays in latent space (re-expansion through ``w_uv`` happens
  outside, as in ``models/mla.py::mla_decode``).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _default_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _full(a):
    """Whole-array resident block with a constant index map (state stays
    in registers/VMEM across the sequential grid)."""
    return pl.BlockSpec(a.shape, lambda t, *_, nd=a.ndim: (0,) * nd)


def _seed_aliased(in_refs, out_refs) -> None:
    """Program 0 copies the aliased pool inputs into the output refs;
    interpret mode seeds aliased outputs anyway, but compiled backends
    leave output windows undefined until written."""
    @pl.when(pl.program_id(0) == 0)
    def _():
        for i_ref, o_ref in zip(in_refs, out_refs):
            o_ref[...] = i_ref[...]


def _online_softmax_walk(pt_ref, t, p_t, page_size, n_heads, v_width,
                         score_fn, value_fn):
    """Shared flash-style page walk: fold pages ``0 .. p_t//page_size``
    of slot ``t`` into ``(m, l, acc)`` carries.  ``score_fn(pid) ->
    (H, ps)`` unmasked f32 scores for one page; ``value_fn(pid, w) ->
    (H, v_width)`` the weighted value/latent contribution."""
    off_in_page = jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)

    def body(p, carry):
        m, l, acc = carry
        pid = pt_ref[t, p]
        s = score_fn(pid)                                  # (H, ps) f32
        kpos = p * page_size + off_in_page                 # (1, ps)
        s = jnp.where(kpos <= p_t, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        w = jnp.exp(s - m_new)                             # masked -> 0
        corr = jnp.exp(m - m_new)                          # first page: 0
        l_new = l * corr + jnp.sum(w, axis=1, keepdims=True)
        acc_new = acc * corr + value_fn(pid, w)
        return m_new, l_new, acc_new

    init = (jnp.full((n_heads, 1), -jnp.inf, jnp.float32),
            jnp.zeros((n_heads, 1), jnp.float32),
            jnp.zeros((n_heads, v_width), jnp.float32))
    n_pages = p_t // page_size + 1     # pages the slot occupies incl. pos
    m, l, acc = jax.lax.fori_loop(0, n_pages, body, init)
    return acc / l


def _row(ref, i):
    """Load row ``i`` of a leading-axis stack, squeezing the axis."""
    idx = (pl.ds(i, 1),) + (slice(None),) * (len(ref.shape) - 1)
    return pl.load(ref, idx)[0]


def _put_cell(ref, page, off, val):
    """Store ``val`` (cell-shaped) at ``ref[page, off]``."""
    idx = (pl.ds(page, 1), pl.ds(off, 1)) + \
        (slice(None),) * (len(ref.shape) - 2)
    return pl.store(ref, idx, val[None, None])


def _gqa_kernel(pt_ref, pos_ref, q_ref, kn_ref, vn_ref, kp_in, vp_in,
                o_ref, kp_ref, vp_ref, *, page_size: int, n_rep: int,
                scale: float):
    t = pl.program_id(0)
    _seed_aliased((kp_in, vp_in), (kp_ref, vp_ref))
    p_t = pos_ref[t]

    # write the new token's K/V into its (page, offset) cell first, so the
    # walk below reads it back like every earlier position (mask <= p_t)
    pg = pt_ref[t, p_t // page_size]
    off = p_t % page_size
    _put_cell(kp_ref, pg, off, _row(kn_ref, t))
    _put_cell(vp_ref, pg, off, _row(vn_ref, t))

    q_t = _row(q_ref, t).astype(jnp.float32)               # (H, hd)
    n_heads, hd = q_t.shape

    def score(pid):
        kb = _row(kp_ref, pid).astype(jnp.float32)         # (ps, Hkv, hd)
        if n_rep > 1:
            kb = jnp.repeat(kb, n_rep, axis=1)
        return jnp.einsum("hd,phd->hp", q_t, kb,
                          preferred_element_type=jnp.float32) * scale

    def value(pid, w):
        vb = _row(vp_ref, pid).astype(jnp.float32)
        if n_rep > 1:
            vb = jnp.repeat(vb, n_rep, axis=1)
        return jnp.einsum("hp,phd->hd", w, vb,
                          preferred_element_type=jnp.float32)

    out = _online_softmax_walk(pt_ref, t, p_t, page_size, n_heads, hd,
                               score, value)
    pl.store(o_ref, (pl.ds(t, 1), slice(None), slice(None)),
             out.astype(o_ref.dtype)[None])


def _mla_kernel(pt_ref, pos_ref, qe_ref, qr_ref, cn_ref, rn_ref,
                cp_in, rp_in, ctx_ref, cp_ref, rp_ref, *, page_size: int,
                scale: float):
    t = pl.program_id(0)
    _seed_aliased((cp_in, rp_in), (cp_ref, rp_ref))
    p_t = pos_ref[t]

    pg = pt_ref[t, p_t // page_size]
    off = p_t % page_size
    _put_cell(cp_ref, pg, off, _row(cn_ref, t))
    _put_cell(rp_ref, pg, off, _row(rn_ref, t))

    q_eff = _row(qe_ref, t).astype(jnp.float32)            # (H, lat)
    q_rope = _row(qr_ref, t).astype(jnp.float32)           # (H, rope)
    n_heads, lat = q_eff.shape

    def score(pid):
        cb = _row(cp_ref, pid).astype(jnp.float32)         # (ps, lat)
        rb = _row(rp_ref, pid).astype(jnp.float32)         # (ps, rope)
        s = (jnp.einsum("hl,pl->hp", q_eff, cb,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("hr,pr->hp", q_rope, rb,
                          preferred_element_type=jnp.float32))
        return s * scale

    def value(pid, w):
        cb = _row(cp_ref, pid).astype(jnp.float32)
        return jnp.einsum("hp,pl->hl", w, cb,
                          preferred_element_type=jnp.float32)

    ctx = _online_softmax_walk(pt_ref, t, p_t, page_size, n_heads, lat,
                               score, value)
    pl.store(ctx_ref, (pl.ds(t, 1), slice(None), slice(None)),
             ctx.astype(ctx_ref.dtype)[None])


def paged_gqa_call(q, k_new, v_new, k_pool, v_pool, page_rows, pos, *,
                   page_size: int, interpret: Optional[bool] = None):
    """Raw kernel launch for the GQA flavor (see ``ops.paged_gqa_decode``
    for the documented public signature)."""
    bs, n_heads, hd = q.shape
    n_rep = n_heads // k_pool.shape[2]
    kern = functools.partial(_gqa_kernel, page_size=page_size,
                             n_rep=n_rep, scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bs,),
        in_specs=[_full(a) for a in (q, k_new, v_new, k_pool, v_pool)],
        out_specs=(_full(q), _full(k_pool), _full(v_pool)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(q.shape, q.dtype),
                   jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)),
        # inputs: [0]=page_rows [1]=pos [2]=q [3]=k_new [4]=v_new
        #         [5]=k_pool [6]=v_pool;  pools alias outputs 1/2
        input_output_aliases={5: 1, 6: 2},
        interpret=_default_interpret(interpret),
    )(page_rows.astype(jnp.int32), pos.astype(jnp.int32),
      q, k_new, v_new, k_pool, v_pool)


def paged_mla_call(q_eff, q_rope, c_new, r_new, c_pool, r_pool, page_rows,
                   pos, *, page_size: int, scale: float,
                   interpret: Optional[bool] = None):
    """Raw kernel launch for the MLA flavor (see ``ops.paged_mla_decode``)."""
    bs = q_eff.shape[0]
    kern = functools.partial(_mla_kernel, page_size=page_size, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bs,),
        in_specs=[_full(a) for a in (q_eff, q_rope, c_new, r_new,
                                     c_pool, r_pool)],
        out_specs=(_full(q_eff), _full(c_pool), _full(r_pool)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(q_eff.shape, q_eff.dtype),
                   jax.ShapeDtypeStruct(c_pool.shape, c_pool.dtype),
                   jax.ShapeDtypeStruct(r_pool.shape, r_pool.dtype)),
        # inputs: [0]=page_rows [1]=pos [2]=q_eff [3]=q_rope [4]=c_new
        #         [5]=r_new [6]=c_pool [7]=r_pool; pools alias outputs 1/2
        input_output_aliases={6: 1, 7: 2},
        interpret=_default_interpret(interpret),
    )(page_rows.astype(jnp.int32), pos.astype(jnp.int32),
      q_eff, q_rope, c_new, r_new, c_pool, r_pool)
