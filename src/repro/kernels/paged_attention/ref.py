"""jnp references for the paged-attention decode ops.

Same signatures and same access contract as ``ops.py`` — in particular the
references only ever index the pool through ``page_rows``, so a pool whose
*unlisted* pages are poisoned (NaN) must still produce finite, identical
outputs.  The hypothesis suite (``tests/test_paged_properties.py``) pins
the Pallas kernels against these references under exactly that poisoning.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _write_cell(pool, page_rows, pos, new, page_size):
    pg = jnp.take_along_axis(page_rows, (pos // page_size)[:, None],
                             axis=1)[:, 0]
    return pool.at[pg, pos % page_size].set(new.astype(pool.dtype))


def _masked_softmax(s, pos, window):
    mask = jnp.arange(window)[None, None, :] <= pos[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    return jax.nn.softmax(s, axis=-1)


def _zero_invalid(cache, pos, window):
    """Zero gathered positions beyond ``pos`` so poisoned (NaN) contents
    of not-yet-occupied cells can't leak through ``0 * NaN`` in the
    einsums — their softmax weight is exactly 0 either way."""
    shape = (cache.shape[0], window) + (1,) * (cache.ndim - 2)
    mask = (jnp.arange(window)[None, :] <= pos[:, None]).reshape(shape)
    return jnp.where(mask, cache, 0)


def paged_gqa_decode_ref(q, k_new, v_new, k_pool, v_pool, page_rows, pos,
                         *, page_size: int) -> Tuple:
    bs, n_heads, hd = q.shape
    k_pool = _write_cell(k_pool, page_rows, pos, k_new, page_size)
    v_pool = _write_cell(v_pool, page_rows, pos, v_new, page_size)
    window = page_rows.shape[1] * page_size
    # gather ONLY the slot's own pages: (bs, window, Hkv, hd)
    kc = _zero_invalid(
        k_pool[page_rows].reshape((bs, window) + k_pool.shape[2:]),
        pos, window)
    vc = _zero_invalid(
        v_pool[page_rows].reshape((bs, window) + v_pool.shape[2:]),
        pos, window)
    rep = n_heads // kc.shape[2]
    if rep > 1:
        kc = jnp.repeat(kc, rep, axis=2)
        vc = jnp.repeat(vc, rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) * hd ** -0.5
    w = _masked_softmax(s, pos, window)
    o = jnp.einsum("bhk,bkhd->bhd", w, vc.astype(jnp.float32))
    return o.astype(q.dtype), k_pool, v_pool


def paged_mla_decode_ref(q_eff, q_rope, c_new, r_new, c_pool, r_pool,
                         page_rows, pos, *, page_size: int,
                         scale: float) -> Tuple:
    bs = q_eff.shape[0]
    c_pool = _write_cell(c_pool, page_rows, pos, c_new, page_size)
    r_pool = _write_cell(r_pool, page_rows, pos, r_new, page_size)
    window = page_rows.shape[1] * page_size
    cc = _zero_invalid(c_pool[page_rows].reshape(bs, window, -1),
                       pos, window)                        # (bs, W, lat)
    rc = _zero_invalid(r_pool[page_rows].reshape(bs, window, -1),
                       pos, window)                        # (bs, W, rope)
    s = (jnp.einsum("bhl,bkl->bhk", q_eff.astype(jnp.float32),
                    cc.astype(jnp.float32))
         + jnp.einsum("bhr,bkr->bhk", q_rope.astype(jnp.float32),
                      rc.astype(jnp.float32))) * scale
    w = _masked_softmax(s, pos, window)
    ctx = jnp.einsum("bhk,bkl->bhl", w, cc.astype(jnp.float32))
    return ctx.astype(q_eff.dtype), c_pool, r_pool
