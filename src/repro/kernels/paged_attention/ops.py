"""Public paged-attention decode ops over block-pool leaves.

Both ops take the pool leaves exactly as :class:`repro.serve.BlockPool`
owns them (page id on axis 0 of the per-layer slice), the per-slot page
table rows and positions, and the new token's projected K/V — and return
``(attention output, updated pool leaves)`` with the new cell written
in-kernel through aliased refs.  The contract both implementations (and
``ref.py``) share:

* only pages listed in ``page_rows[t, : pos[t] // page_size + 1]`` are
  read for slot ``t`` — never another slot's pages, never the tail of the
  page table (property-tested against poisoned pool contents);
* positions beyond ``pos[t]`` are masked out of the softmax;
* the single cell ``(page_rows[t, pos[t] // page_size], pos[t] %
  page_size)`` is written with the new token's K/V before attention, so
  position ``pos[t]`` attends to itself.

``interpret=None`` follows the repo-wide kernel default (compiled on TPU,
Pallas interpret mode elsewhere) so CI exercises the identical walk.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from . import kernel


def paged_gqa_decode(q, k_new, v_new, k_pool, v_pool, page_rows, pos, *,
                     page_size: int,
                     interpret: Optional[bool] = None) -> Tuple:
    """GQA decode against a paged K/V pool.

    q ``(bs, H, hd)``; k_new/v_new ``(bs, Hkv, hd)``; pools
    ``(P, page_size, Hkv, hd)``; page_rows ``(bs, max_pages)`` int32;
    pos ``(bs,)`` int32.  Returns ``(o (bs, H, hd), k_pool', v_pool')``.
    """
    return kernel.paged_gqa_call(q, k_new, v_new, k_pool, v_pool,
                                 page_rows, pos, page_size=page_size,
                                 interpret=interpret)


def paged_mla_decode(q_eff, q_rope, c_new, r_new, c_pool, r_pool,
                     page_rows, pos, *, page_size: int, scale: float,
                     interpret: Optional[bool] = None) -> Tuple:
    """Weight-absorbed MLA decode against the compressed latent pool.

    q_eff ``(bs, H, lat)`` (q_nope absorbed through ``w_uk``); q_rope
    ``(bs, H, rope)``; c_new ``(bs, lat)``; r_new ``(bs, rope)``; pools
    ``(P, page_size, lat)`` / ``(P, page_size, rope)``.  Returns
    ``(ctx (bs, H, lat), c_pool', r_pool')`` — the caller re-expands the
    latent context through ``w_uv`` (``models/mla.py::mla_decode``).
    """
    return kernel.paged_mla_call(q_eff, q_rope, c_new, r_new, c_pool,
                                 r_pool, page_rows, pos,
                                 page_size=page_size, scale=scale,
                                 interpret=interpret)


def pages_occupied(pos: jnp.ndarray, page_size: int) -> jnp.ndarray:
    """Pages slot(s) at position ``pos`` occupy including the cell being
    written this step — the kernel's per-slot walk bound."""
    return pos // page_size + 1
