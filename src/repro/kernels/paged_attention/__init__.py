"""Paged-attention decode kernel: in-kernel page-table walk.

One Pallas kernel per attention flavor (GQA, MLA weight-absorbed) that
decodes a batch of slots directly against the serving tier's paged block
pool — scalar-prefetched page-table rows drive an in-kernel online-softmax
walk over exactly the pages each slot occupies, and the new token's K/V is
written into its single ``(page, offset)`` cell through aliased output
refs.  Zero gather, zero scatter; DESIGN.md §Serving ("Paged-attention
kernel")."""

from .ops import paged_gqa_decode, paged_mla_decode
from .ref import paged_gqa_decode_ref, paged_mla_decode_ref

__all__ = [
    "paged_gqa_decode",
    "paged_mla_decode",
    "paged_gqa_decode_ref",
    "paged_mla_decode_ref",
]
