"""Pallas TPU kernels for the Barnes-Hut interaction tasks (paper §4.2).

The hot spots are the particle-particle tasks (self and pair): dense
(Ni × Nj) interaction blocks.  TPU adaptation (DESIGN.md §2):

  * layout is (3, N): coordinates live in the 8-sublane dim, particles in
    the 128-lane dim;
  * the i-side is tiled by ``TILE_I`` (grid dim 0) with the full j-side
    resident in VMEM — a task's j-side is a cell of ≤ n_task ≈ 5000
    particles ≈ 80 KiB, far under VMEM;
  * inputs are zero-mass padded to lane multiples by ops.py, so no masking
    is needed for ragged sizes (a zero-mass source contributes nothing);
  * the self kernel masks the i==j diagonal via the grid offset.

The (Ni × Nj) force evaluation is VPU element-wise work with an MXU-free
inner product over the 3 coordinate planes (unrolled), which keeps the
arithmetic intensity at ~O(Nj) flops per byte of i-side traffic — the same
compute-per-memory-access argument the paper makes for task granularity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DEFAULT_EPS

TILE_I = 128


def acc_block(xi, xj, mj_row, eps):
    """xi: (3,TI), xj: (3,NJ), mj_row: (1,NJ) → displacement planes and the
    m_j/r³ weight matrix; shared by the per-task kernels here and the
    engine megakernel (repro.engine.megakernel, DESIGN.md §Engine)."""
    ti = xi.shape[1]
    nj = xj.shape[1]
    dx0 = xj[0].reshape(1, nj) - xi[0].reshape(ti, 1)
    dx1 = xj[1].reshape(1, nj) - xi[1].reshape(ti, 1)
    dx2 = xj[2].reshape(1, nj) - xi[2].reshape(ti, 1)
    r2 = dx0 * dx0 + dx1 * dx1 + dx2 * dx2 + eps * eps
    w = jax.lax.rsqrt(r2)
    w = w * w * w * mj_row                       # m_j / r^3, (TI, NJ)
    return dx0, dx1, dx2, w


def _pair_kernel(xi_ref, xj_ref, mj_ref, out_ref, *, eps):
    xi = xi_ref[...]
    dx0, dx1, dx2, w = acc_block(xi, xj_ref[...], mj_ref[...], eps)
    out_ref[...] = jnp.stack([
        jnp.sum(dx0 * w, axis=1),
        jnp.sum(dx1 * w, axis=1),
        jnp.sum(dx2 * w, axis=1),
    ])


def _self_kernel(x_ref, m_ref, xi_ref, out_ref, *, eps):
    i = pl.program_id(0)
    ti = xi_ref.shape[1]
    nj = x_ref.shape[1]
    xi = xi_ref[...]
    dx0, dx1, dx2, w = acc_block(xi, x_ref[...], m_ref[...], eps)
    gi = i * ti + jax.lax.broadcasted_iota(jnp.int32, (ti, 1), 0)
    gj = jax.lax.broadcasted_iota(jnp.int32, (1, nj), 1)
    w = jnp.where(gi == gj, jnp.zeros_like(w), w)   # exclude self-pairs
    out_ref[...] = jnp.stack([
        jnp.sum(dx0 * w, axis=1),
        jnp.sum(dx1 * w, axis=1),
        jnp.sum(dx2 * w, axis=1),
    ])


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def acc_pair(xi, xj, mj, *, eps: float = DEFAULT_EPS, interpret: bool = True):
    """xi (3,Ni), xj (3,Nj), mj (Nj,); Ni, Nj multiples of TILE_I/lane size
    (ops.py pads).  Returns (3,Ni) accelerations on the i side."""
    ni, nj = xi.shape[1], xj.shape[1]
    grid = (ni // TILE_I,) if ni % TILE_I == 0 else (1,)
    ti = TILE_I if ni % TILE_I == 0 else ni
    return pl.pallas_call(
        functools.partial(_pair_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, ti), lambda i: (0, i)),
            pl.BlockSpec((3, nj), lambda i: (0, 0)),
            pl.BlockSpec((1, nj), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((3, ti), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((3, ni), xi.dtype),
        interpret=interpret,
    )(xi, xj, mj.reshape(1, nj))


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def acc_self(x, m, *, eps: float = DEFAULT_EPS, interpret: bool = True):
    """All-pairs within one set (3,N), diagonal excluded."""
    n = x.shape[1]
    grid = (n // TILE_I,) if n % TILE_I == 0 else (1,)
    ti = TILE_I if n % TILE_I == 0 else n
    return pl.pallas_call(
        functools.partial(_self_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((3, ti), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((3, ti), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((3, n), x.dtype),
        interpret=interpret,
    )(x, m.reshape(1, n), x)
