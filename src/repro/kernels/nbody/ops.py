"""Public ops for the N-body kernels: zero-mass padding to lane multiples +
backend dispatch (Pallas kernel vs jnp oracle)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref
from .ref import DEFAULT_EPS

LANE = 128


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_lane(a: jnp.ndarray, n: int) -> jnp.ndarray:
    """Pad the last dim up to a LANE multiple (zeros)."""
    target = max(LANE, ((n + LANE - 1) // LANE) * LANE)
    if target == n:
        return a
    pad = [(0, 0)] * (a.ndim - 1) + [(0, target - n)]
    return jnp.pad(a, pad)


def acc_pair(xi, xj, mj, eps: float = DEFAULT_EPS, backend: str = "pallas"):
    if backend == "ref":
        return ref.acc_pair_ref(xi, xj, mj, eps)
    ni, nj = xi.shape[1], xj.shape[1]
    out = kernel.acc_pair(_pad_lane(xi, ni), _pad_lane(xj, nj),
                          _pad_lane(mj, nj), eps=eps, interpret=_interpret())
    return out[:, :ni]


def acc_self(x, m, eps: float = DEFAULT_EPS, backend: str = "pallas"):
    if backend == "ref":
        return ref.acc_self_ref(x, m, eps)
    n = x.shape[1]
    out = kernel.acc_self(_pad_lane(x, n), _pad_lane(m, n), eps=eps,
                          interpret=_interpret())
    return out[:, :n]
