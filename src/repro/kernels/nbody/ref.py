"""Pure-jnp oracles for the N-body interaction kernels (paper §4.2).

Plummer-softened gravity, G = 1:
    a_i += m_j (x_j - x_i) / (|x_j - x_i|^2 + eps^2)^{3/2}

Layout is (3, N) — coordinates in the sublane dim, particles in the lane
dim — the TPU-native choice (N is the 128-multiple vector axis).
"""

from __future__ import annotations

import jax.numpy as jnp

DEFAULT_EPS = 1e-4


def acc_pair_ref(xi: jnp.ndarray, xj: jnp.ndarray, mj: jnp.ndarray,
                 eps: float = DEFAULT_EPS) -> jnp.ndarray:
    """Accelerations on particles ``xi`` (3,Ni) due to sources ``xj``
    (3,Nj) with masses ``mj`` (Nj,).  No self-exclusion (disjoint sets)."""
    dx = xj[:, None, :] - xi[:, :, None]          # (3, Ni, Nj)
    r2 = jnp.sum(dx * dx, axis=0) + eps * eps     # (Ni, Nj)
    inv_r3 = r2 ** -1.5
    w = inv_r3 * mj[None, :]                      # (Ni, Nj)
    return jnp.einsum("dij,ij->di", dx, w)        # (3, Ni)


def acc_self_ref(x: jnp.ndarray, m: jnp.ndarray,
                 eps: float = DEFAULT_EPS) -> jnp.ndarray:
    """All-pairs accelerations within one set, self-pairs excluded."""
    n = x.shape[1]
    dx = x[:, None, :] - x[:, :, None]            # (3, N, N)
    r2 = jnp.sum(dx * dx, axis=0) + eps * eps
    inv_r3 = r2 ** -1.5
    mask = 1.0 - jnp.eye(n, dtype=x.dtype)
    w = inv_r3 * m[None, :] * mask
    return jnp.einsum("dij,ij->di", dx, w)


def acc_direct_ref(x: jnp.ndarray, m: jnp.ndarray,
                   eps: float = DEFAULT_EPS) -> jnp.ndarray:
    """O(N^2) direct sum over the whole particle set — the ground truth the
    Barnes-Hut approximation is measured against."""
    return acc_self_ref(x, m, eps)
