"""Continuous-batching serving tier on the QuickSched execution stack.

``blockpool`` owns paged cache memory (pages as hierarchical resources,
admission as a conflict round), ``service`` runs the persistent
prefill/decode loop through the core backends, and ``traffic`` generates
open-loop synthetic request streams for the serving benchmark.
"""

from .blockpool import AdmissionConflict, BlockPool, TT_PREFILL
from .service import (DECODE_PATHS, ENG_DECODE, GenerateService, Request,
                      SamplingParams, TT_DECODE)
from .traffic import SyntheticRequest, open_loop_trace

__all__ = [
    "AdmissionConflict", "BlockPool", "TT_PREFILL",
    "DECODE_PATHS", "ENG_DECODE", "GenerateService", "Request",
    "SamplingParams", "TT_DECODE",
    "SyntheticRequest", "open_loop_trace",
]
