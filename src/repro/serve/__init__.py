"""Continuous-batching serving tier on the QuickSched execution stack.

``blockpool`` owns paged cache memory (pages as hierarchical resources,
admission as a conflict round), ``service`` runs the persistent
prefill/decode loop through the core backends, ``traffic`` generates
open-loop synthetic request streams for the serving benchmark, and
``faults`` is the deterministic chaos-injection harness behind the
service's robustness layer (deadlines, preemption with page
reclamation, guarded decode with a degrade ladder — DESIGN.md
§Robustness).
"""

from .blockpool import AdmissionConflict, BlockPool, TT_PREFILL
from .faults import FAULT_KINDS, FaultEvent, FaultPlan
from .service import (DECODE_PATHS, ENG_DECODE, GenerateService, QueueFull,
                      Request, SamplingParams, ServiceStalled, TT_DECODE)
from .traffic import SyntheticRequest, open_loop_trace

__all__ = [
    "AdmissionConflict", "BlockPool", "TT_PREFILL",
    "FAULT_KINDS", "FaultEvent", "FaultPlan",
    "DECODE_PATHS", "ENG_DECODE", "GenerateService", "QueueFull",
    "Request", "SamplingParams", "ServiceStalled", "TT_DECODE",
    "SyntheticRequest", "open_loop_trace",
]
