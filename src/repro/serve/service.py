"""Continuous-batching generate service on the device-resident scheduler.

The seed's ``launch/serve.py`` was a host-driven static-batch loop: prefill
a fixed batch, decode until the *slowest* request finishes, repeat.  This
module replaces it with a persistent service in the BatchGenerateService
mold (SHARK-Engine's ``service_v1``): an admission queue feeding a fixed
set of batch slots, requests joining and leaving mid-stream, and
batch-shape-specialized jitted entry points.  The QuickSched machinery is
not beside the serving path — it *is* the serving path:

* **Admission is a conflict round.**  Arriving requests take pages from
  the :class:`~repro.serve.blockpool.BlockPool` free list; the batch
  lowers through ``core.plan.lower`` as one PREFILL task per request
  locking its pages, must prove conflict-free (single round, one
  write-coloring phase), and then *executes through the ``rounds``
  backend* — ``BatchSpec(TT_PREFILL).run_one`` is the jitted prefill
  entry point that writes the prompt KV into the request's pages.
* **Decode is an engine task family.**  Each service tick lowers the
  active slots as DECODE tasks (one locked state resource per slot) and
  runs them through the ``engine`` backend: ``BatchSpec.encode`` emits
  ``[DECODE, slot, pos]`` descriptor rows and the family's
  :class:`~repro.core.backends.EngineHooks` round function decodes every
  slot in one jitted dispatch per tick.  *Which* round function depends
  on a capability probe of the backend registry
  (``get_backend("engine").compiled_kernels()``):

  - ``kernel`` — the paged-attention megakernel
    (``kernels/paged_attention``) walks each slot's page table
    *in-kernel* with an online softmax over only the pages the slot
    occupies and writes the new K/V cell through aliased refs — zero
    gather, zero scatter (natively compiled backends; forceable
    elsewhere, where it runs in Pallas interpret mode for conformance);
  - ``bounded`` — the jitted gather fallback, window-bounded: it
    gathers/attends only ``ceil((max active pos + 1)/page_size)`` pages
    per slot (a per-tick static from the descriptor positions), keeping
    the work ∝ occupied pages contract on hosts without compiled Pallas
    (the CPU/CI default);
  - ``gather`` — PR 6's full-``max_seq``-window path, kept as the
    conformance oracle the other two are pinned against token-for-token
    (and the only path for the non-paged SSM family).

* **Sampling is part of the decode family's buffers.**  Greedy argmax is
  the default and the conformance oracle; :class:`SamplingParams` with
  ``temperature > 0`` (plus optional top-k) threads one PRNG key per slot
  through the engine buffers — re-seeded per request from
  ``fold_in(seed, rid)`` at admission, split once per sampled token — so
  a request's token stream is deterministic under a fixed seed no matter
  how requests interleave.
* **The plan cache is the compiled-module registry.**  Admission and
  decode graphs are canonical (structure depends only on the batch
  shape), so ``core.plan``'s structural-hash cache maps each batch shape
  to its lowered plan, and the engine's segment-runner jit cache maps
  each plan layout to a compiled executable — the ``prefill_bs{n}`` /
  ``decode_bs{n}`` entry-point dicts of SHARK's service, derived instead
  of hand-registered (asserted via ``plan_cache_info()`` in
  ``tests/test_serve.py``).

**Robustness (DESIGN.md §Robustness).**  The same invariant that makes
continuous batching correct — exclusively lockable resources let
conflicting tasks run in *any order*, just not concurrently — is what
makes failure recovery safe: a preempted request's pages go back to the
pool intact and its PREFILL/DECODE tasks are simply re-lowered later as
another conflict round.  The service's failure model:

* **Lifecycle control** — per-request deadlines (absolute, on the
  service's virtual clock) and :meth:`GenerateService.cancel`; both
  evict active victims through :meth:`_preempt`, which scatters the slot
  out of the device-resident engine buffers, returns its pages to the
  free list (conservation asserted), and either requeues the request for
  re-admission (its prefix — prompt + tokens so far — is recomputed
  through the normal prefill family) or retires it terminally
  (``cancelled`` / ``deadline_exceeded``).
* **Guarded decode** — with ``guard=True`` (default) every decode round
  also writes a per-slot finiteness flag (``isfinite`` over the round's
  logits).  A slot that trips it is retried once, in-tick, on the
  ``gather`` reference round function; a slot that trips the retry too
  is preempted and re-admitted.  Repeated faults additionally *degrade*
  the per-tick round function down the capability ladder
  (kernel → bounded → gather) with exponential backoff before promoting
  back — PR 8's one-shot static probe generalized into a per-tick
  decision.
* **Chaos harness** — a seeded :class:`~repro.serve.faults.FaultPlan`
  threaded through :meth:`step` makes every path above deterministically
  reachable (``tests/test_faults.py``, the CI chaos smoke).

Every transition is metered (``serve.preemptions`` / ``serve.retries`` /
``serve.rejected`` / ``serve.deadline_exceeded`` / ``serve.cancelled``)
and traced (``request.preempted`` spans, counter tracks) through the
``repro.obs`` registry and Perfetto export.

Continuous-batched decode is token-for-token identical to the sequential
``serving.prefill``/``decode_step`` reference per request (conformance
tier in ``tests/test_serve.py``): prefill is the same B=1 call the
reference makes, batched paged decode matches the reference bitwise
(dense) or to float tolerance below greedy-argmax sensitivity (MLA/SSM),
and stale contents of reused pages are fully masked beyond ``pos``.
"""

from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import EngineHooks, run_plan
from repro.core.graph import QSched
from repro.core.plan import BatchSpec, lower
from repro.models import serving as serving_mod
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry

from .blockpool import AdmissionConflict, TT_PREFILL, BlockPool
from .faults import FaultPlan

TT_DECODE = 1       # task type of the decode family
ENG_DECODE = 1      # engine descriptor row etype for a decode item

SUPPORTED_FAMILIES = ("dense", "moe", "ssm")
DECODE_PATHS = ("auto", "kernel", "bounded", "gather")
# capability ladder, fastest first — the degrade walk moves right
DECODE_LADDER = ("kernel", "bounded", "gather")

# guard-flag lane values (one int32 per slot in the engine buffers)
FLAG_OK = 0         # round produced finite logits
FLAG_FAULT = 1      # finiteness check tripped
FLAG_POISON = 2     # armed by chaos injection: round NaNs this slot's logits

# terminal request states (Request.status; "queued"/"active" are transient)
ST_DONE = "done"
ST_CANCELLED = "cancelled"
ST_DEADLINE = "deadline_exceeded"
TERMINAL_STATES = (ST_DONE, ST_CANCELLED, ST_DEADLINE)


class QueueFull(RuntimeError):
    """``submit()`` refused: the admission queue is at ``max_queue``."""

    def __init__(self, msg: str, *, queue_depth: int, max_queue: int):
        super().__init__(msg)
        self.queue_depth = queue_depth
        self.max_queue = max_queue


class ServiceStalled(RuntimeError):
    """``run_until_complete`` exhausted its step budget with requests
    still in flight.  Carries the diagnostic snapshot (queue depth,
    active slots, last tick that made progress) instead of failing
    silently — a stall is an operational bug (pool too small for a
    queued request, a fault loop, a budget set too low), and the
    snapshot says which."""

    def __init__(self, msg: str, *, queue_depth: int, active_slots: int,
                 last_progress_tick: int, steps: int):
        super().__init__(msg)
        self.queue_depth = queue_depth
        self.active_slots = active_slots
        self.last_progress_tick = last_progress_tick
        self.steps = steps


@dataclass(frozen=True)
class SamplingParams:
    """How next tokens are chosen from decode logits.

    The default (``temperature == 0``) is greedy argmax — the conformance
    oracle, bitwise-independent of the PRNG buffer.  ``temperature > 0``
    samples from the (optionally top-k-truncated) tempered distribution
    with one threefry key per slot threaded through the engine buffers;
    ``seed`` plus the request id fully determine a request's stream."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclass
class Request:
    """One generation request moving through the service.  The ``t_*``
    timestamps (submit → admit → first token → complete, on the service's
    virtual clock) are always recorded — they feed the TTFT/latency
    histograms and, when a tracer is enabled, the per-request lifecycle
    spans.  ``status`` walks queued → active → one of
    :data:`TERMINAL_STATES` (a preempted request goes back to queued);
    ``deadline_s`` is absolute on the service clock, ``None`` = no
    deadline."""
    rid: int
    prompt: np.ndarray                 # (plen,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    pages: List[int] = field(default_factory=list)
    slot: int = -1
    pos: int = 0
    done: bool = False
    status: str = "queued"
    deadline_s: Optional[float] = None
    preemptions: int = 0
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def tokens(self) -> List[int]:
        return list(self.generated)

    @property
    def ttft_s(self) -> float:
        """Submit → first token (0.0 until the first token exists)."""
        return self.t_first - self.t_submit if self.t_first else 0.0

    @property
    def latency_s(self) -> float:
        """Submit → retire (0.0 until the request completes)."""
        return self.t_done - self.t_submit if self.t_done else 0.0

    def feed_tokens(self) -> np.ndarray:
        """What (re-)admission prefills: the original prompt plus every
        token generated so far — a preempted request's prefix is
        recomputed through the normal prefill family, and greedy prefill
        of this feed reproduces exactly the token its evicted decode
        would have produced next."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def total_positions(self) -> int:
        """Cache positions the request can ever touch (constant across
        preemptions: generated tokens move from budget to feed)."""
        return int(self.prompt.size) + self.max_new_tokens - 1


def _decode_row_access(row: Sequence[int]) -> Tuple[Tuple, Tuple]:
    """A decode item reads and writes only its own slot's pages/state, so
    the slot id is the state-row key: distinct slots never collide and
    every decode round colors to one grid-parallel phase."""
    key = ("slot", int(row[1]))
    return ((key,), (key,))


def _finish_decode(leaves, pt, tok, pos, keys, flags, slots, p_b, logits,
                   sampling: SamplingParams, guard: bool):
    """Common decode-round tail: pick next tokens and advance the slot
    state.  Greedy leaves the key buffer untouched (bitwise oracle).

    With ``guard`` the tail also (a) honors the chaos poison lane —
    a slot whose flag was armed to :data:`FLAG_POISON` gets its logits
    NaNed *here, inside the jitted round*, so injected faults flow
    through the identical detection path an organic NaN would — and
    (b) writes the per-slot finiteness verdict back into the flags
    buffer, which the service reads once per tick."""
    if guard:
        poisoned = flags[slots] == FLAG_POISON
        logits = jnp.where(poisoned[:, None], jnp.nan,
                           logits.astype(jnp.float32))
        ok = jnp.isfinite(logits).all(axis=-1)
        flags = flags.at[slots].set(
            jnp.where(ok, FLAG_OK, FLAG_FAULT).astype(jnp.int32))
    nxt, new_keys = serving_mod.sample_tokens(
        logits, keys[slots], sampling.temperature, sampling.top_k)
    if sampling.temperature > 0.0:
        keys = keys.at[slots].set(new_keys)
    return (leaves, pt, tok.at[slots].set(nxt),
            pos.at[slots].set(p_b + 1), keys, flags)


def _make_decode_round_fn(cfg, paged: bool, page_size: int, max_pages: int,
                          sampling: SamplingParams,
                          guard: bool) -> Callable:
    """The full-window gather round function — PR 6's path, now the
    conformance oracle (``decode_path="gather"``), the retry/degrade
    floor of the fallback ladder, and the only path for the non-paged
    SSM family.  Layout: ``desc[i] = [ENG_DECODE, slot, pos]``; buffers =
    ``(pool leaves, page_tables, tok, pos, keys, flags)``; statics =
    ``(params,)``.  Stable object per service, so the engine's jitted
    segment runners cache per batch shape."""

    def decode_round(desc, bounds, statics, buffers):
        del bounds                     # single write-colored phase
        params = statics[0]
        leaves, pt, tok, pos, keys, flags = buffers
        slots = desc[:, 1]
        p_b = desc[:, 2]
        bs = desc.shape[0]
        ptb = pt[slots]                                     # (bs, MP)
        if paged:
            cache = {
                k: leaf[:, ptb].reshape(
                    (leaf.shape[0], bs, max_pages * page_size)
                    + leaf.shape[3:])
                for k, leaf in leaves.items()}
        else:
            cache = {k: leaf[:, ptb[:, 0]] for k, leaf in leaves.items()}
        logits, new_cache = serving_mod.decode_step(
            params, cfg, cache, tok[slots][:, None], p_b)
        out = dict(leaves)
        if paged:
            # the step wrote exactly position p_b of each slot's cache:
            # scatter that one (page, offset) cell back into the pool
            page_ids = jnp.take_along_axis(
                ptb, (p_b // page_size)[:, None], axis=1)[:, 0]
            off = p_b % page_size
            bidx = jnp.arange(bs)
            for k, leaf in leaves.items():
                val = new_cache[k][:, bidx, p_b]            # (L, bs, ...)
                out[k] = leaf.at[:, page_ids, off].set(val)
        else:
            sid = ptb[:, 0]
            for k, leaf in leaves.items():
                out[k] = leaf.at[:, sid].set(new_cache[k])
        return _finish_decode(out, pt, tok, pos, keys, flags, slots, p_b,
                              logits, sampling, guard)

    return decode_round


def _make_bounded_decode_round_fn(cfg, page_size: int,
                                  sampling: SamplingParams,
                                  guard: bool) -> Callable:
    """Window-bounded gather round function (``decode_path="bounded"``,
    the default where Pallas is interpret-only): identical math to the
    full-window path, but it gathers/attends only the first ``n_walk``
    pages per slot, where ``n_walk = max(pos)//page_size + 1`` over the
    round is carried as the *shape* of a dummy static
    (``statics = (params, walk_token)``) so the engine re-specializes
    exactly when the page-walk bound grows — work stays ∝ occupied pages,
    like the kernel.  Bitwise-equal to the full window: every truncated
    position is masked to ``-inf`` there anyway."""

    def decode_round(desc, bounds, statics, buffers):
        del bounds
        params, walk = statics
        n_walk = walk.shape[0]         # static page-walk bound this round
        leaves, pt, tok, pos, keys, flags = buffers
        slots = desc[:, 1]
        p_b = desc[:, 2]
        bs = desc.shape[0]
        win = pt[slots][:, :n_walk]                         # (bs, n_walk)
        cache = {
            k: leaf[:, win].reshape(
                (leaf.shape[0], bs, n_walk * page_size) + leaf.shape[3:])
            for k, leaf in leaves.items()}
        logits, new_cache = serving_mod.decode_step(
            params, cfg, cache, tok[slots][:, None], p_b)
        page_ids = jnp.take_along_axis(
            win, (p_b // page_size)[:, None], axis=1)[:, 0]
        off = p_b % page_size
        bidx = jnp.arange(bs)
        out = {k: leaf.at[:, page_ids, off].set(
                   new_cache[k][:, bidx, p_b])
               for k, leaf in leaves.items()}
        return _finish_decode(out, pt, tok, pos, keys, flags, slots, p_b,
                              logits, sampling, guard)

    return decode_round


def _make_paged_decode_round_fn(cfg, page_size: int,
                                sampling: SamplingParams,
                                guard: bool) -> Callable:
    """The paged-attention megakernel round function
    (``decode_path="kernel"``): hand the pool leaves, page-table rows and
    descriptor positions straight to ``serving.decode_step_paged``, which
    walks each slot's pages in-kernel and writes the new cell through
    aliased refs — no gather, no scatter, no ``max_seq``-shaped
    intermediate."""

    def decode_round(desc, bounds, statics, buffers):
        del bounds
        params = statics[0]
        leaves, pt, tok, pos, keys, flags = buffers
        slots = desc[:, 1]
        p_b = desc[:, 2]
        logits, new_leaves = serving_mod.decode_step_paged(
            params, cfg, leaves, pt[slots], tok[slots][:, None], p_b,
            page_size=page_size)
        return _finish_decode(new_leaves, pt, tok, pos, keys, flags, slots,
                              p_b, logits, sampling, guard)

    return decode_round


class GenerateService:
    """Continuous-batching serving engine over a paged block pool.

    ``max_batch`` is the number of concurrent decode slots, ``max_seq``
    the per-request cache capacity (prompt + generated - 1 positions must
    fit), ``page_size`` the positions per pool page.  ``n_pages``
    defaults to exactly enough pages to fill every slot
    (``max_batch * max_seq / page_size``); set it lower to make paging
    pressure the admission bottleneck.

    Robustness knobs: ``max_queue`` bounds the admission queue
    (``submit`` raises :class:`QueueFull` past it); ``deadline_ms`` is a
    default per-request deadline (``submit(deadline_ms=...)`` overrides);
    ``guard`` enables the post-round finiteness check and the
    retry/degrade/preempt ladder; ``faults`` installs a
    :class:`~repro.serve.faults.FaultPlan` (requires ``guard``)."""

    def __init__(self, params: Any, cfg, *, max_batch: int = 4,
                 max_seq: int = 64, page_size: int = 8,
                 n_pages: Optional[int] = None, nr_lanes: int = 1,
                 decode_path: str = "auto",
                 sampling: Optional[SamplingParams] = None,
                 max_queue: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 guard: bool = True,
                 faults: Optional[FaultPlan] = None):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"GenerateService supports families {SUPPORTED_FAMILIES}, "
                f"not {cfg.family!r} (extra per-request inputs / trunk+"
                f"shared split not wired up yet)")
        if decode_path not in DECODE_PATHS:
            raise ValueError(
                f"decode_path must be one of {DECODE_PATHS}, "
                f"not {decode_path!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        self.params = params
        self.cfg = cfg
        self.paged = cfg.family != "ssm"
        self.sampling = sampling or SamplingParams()
        self.guard = bool(guard)
        # capability probe, not platform sniffing: the kernel path wins
        # only where the engine backend compiles Pallas natively
        if not self.paged:
            decode_path = "gather"     # SSM state is O(1) — nothing paged
        elif decode_path == "auto":
            from repro.core.backends import get_backend
            decode_path = ("kernel"
                           if get_backend("engine").compiled_kernels()
                           else "bounded")
        self.decode_path = decode_path
        if self.paged and max_seq % page_size != 0:
            raise ValueError("max_seq must be a multiple of page_size")
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.nr_lanes = nr_lanes
        self.max_pages = max_seq // page_size if self.paged else 1
        if n_pages is None:
            n_pages = max_batch * self.max_pages
        self.pool = BlockPool(n_pages, page_size, cfg=cfg)
        self.max_queue = max_queue
        self.deadline_ms = deadline_ms

        # slot state lives on device between steps (page table, last
        # token, position, sampling key, guard flag) — the engine's
        # buffers are passed straight through with no per-step
        # host<->device conversion
        self._pt = jnp.zeros((max_batch, self.max_pages), jnp.int32)
        self._tok = jnp.zeros((max_batch,), jnp.int32)
        self._pos = jnp.zeros((max_batch,), jnp.int32)
        # one raw threefry key row per slot; admission overwrites the
        # slot's row with fold_in(seed, rid) so a request's sample stream
        # depends only on (seed, rid), not on scheduling history
        self._keys = jnp.zeros((max_batch, 2), jnp.uint32)
        self._flags = jnp.zeros((max_batch,), jnp.int32)
        self._free_slots: List[int] = list(range(max_batch - 1, -1, -1))
        self._active: Dict[int, Request] = {}
        self._queue: Deque[Request] = deque()
        self._requests: Dict[int, Request] = {}    # rid -> live request
        self._next_rid = 0

        # batch-shape-specialized jitted entry points: prefill per
        # (prompt length, batch size) — SHARK's prefill_bs{n} dict, keyed
        # by shape instead of symbol name, with same-plen admissions
        # sharing one batched entry point; decode specializations live in
        # the engine's segment-runner jit cache, one per batch size seen
        self._prefill_fns: Dict[Tuple[int, int], Callable] = {}
        self.decode_batch_sizes_seen: set = set()

        self.registry = {
            TT_PREFILL: BatchSpec(run_one=self._run_prefill,
                                  run_batch=self._run_prefill_batch),
            TT_DECODE: BatchSpec(run_one=self._no_host_decode,
                                 encode=self._encode_decode),
        }
        # degrade ladder: the selected path plus everything below it.
        # One EngineHooks (and one stable round_fn object, for the jit
        # caches) per rung; the last rung is always the gather oracle —
        # it is also the in-tick retry path.
        self._ladder: Tuple[str, ...] = (
            ("gather",) if not self.paged
            else DECODE_LADDER[DECODE_LADDER.index(self.decode_path):])
        self._level = 0                 # current rung (0 = selected path)
        self._fault_streak = 0          # consecutive faulted ticks
        self._cooldown = 0              # clean ticks before promotion
        self._hooks_by_path: Dict[str, EngineHooks] = {
            path: self._make_hooks(path) for path in self._ladder}

        # robustness bookkeeping
        self._faults: Optional[FaultPlan] = None
        self.faults_fired: List[Tuple[int, Any, bool]] = []
        self.faulted_rids: set = set()   # preempted / cancelled / expired
        self.retried_rids: set = set()   # recovered by the in-tick retry
        self._poison_budget: Dict[int, int] = {}   # slot -> armed rounds
        self._admission_fault = False
        self._skew = 0.0                 # virtual-clock offset (stalls)
        self._last_progress_tick = -1
        self.inject(faults)

        # per-service metrics registry (DESIGN.md §Observability): exact
        # lifecycle counters (the old ad-hoc stats dict, now typed),
        # occupancy/depth gauges sampled every tick, TTFT + end-to-end
        # latency histograms.  `stats` stays dict-shaped for callers.
        self.metrics = MetricsRegistry()
        self._counters = {k: self.metrics.counter(f"serve.{k}")
                          for k in ("submitted", "admitted", "retired",
                                    "steps", "decode_items",
                                    "generated_tokens", "pages_attended",
                                    "preemptions", "retries", "rejected",
                                    "deadline_exceeded", "cancelled",
                                    "faults_injected")}
        self._g_pages = self.metrics.gauge("serve.pages_in_use")
        self._g_queue = self.metrics.gauge("serve.queue_depth")
        self._g_active = self.metrics.gauge("serve.active_slots")
        self._g_level = self.metrics.gauge("serve.degrade_level")
        self._h_ttft = self.metrics.histogram("serve.ttft_s")
        self._h_latency = self.metrics.histogram("serve.latency_s")

    def _make_hooks(self, path: str) -> EngineHooks:
        if path == "kernel":
            round_fn = _make_paged_decode_round_fn(
                self.cfg, self.pool.page_size, self.sampling, self.guard)
        elif path == "bounded":
            round_fn = _make_bounded_decode_round_fn(
                self.cfg, self.pool.page_size, self.sampling, self.guard)
        else:
            round_fn = _make_decode_round_fn(
                self.cfg, self.paged, self.pool.page_size, self.max_pages,
                self.sampling, self.guard)
        return EngineHooks(
            arg_width=2,
            round_fn=round_fn,
            statics=functools.partial(self._statics_for, path),
            buffers=self._buffers,
            writeback=self._writeback,
            row_access=_decode_row_access,
            fuse_rounds=False,
            donate=False,
        )

    @property
    def stats(self) -> Dict[str, int]:
        """Exact lifecycle counts as a plain dict — backward-compatible
        view over the metrics registry (``tests/test_serve.py`` asserts
        these counts; ``GenerateService.metrics`` is the full registry)."""
        return {k: c.value for k, c in self._counters.items()}

    @property
    def decode_path_active(self) -> str:
        """The rung of the degrade ladder the next tick will run on
        (equals ``decode_path`` until a fault degrades it)."""
        return self._ladder[self._level]

    @property
    def hooks(self) -> EngineHooks:
        """EngineHooks for the currently active decode path."""
        return self._hooks_by_path[self.decode_path_active]

    def _now(self) -> float:
        """The service's virtual clock: the tracer clock plus any stall
        skew injected by the chaos harness.  Deadlines and request
        timestamps live on this clock so tests can expire deadlines
        without sleeping."""
        return _trace.now() + self._skew

    # -- public API ----------------------------------------------------------
    def inject(self, faults: Optional[FaultPlan]) -> None:
        """Install (or clear) a chaos plan.  Requires the decode guard:
        injected NaNs must flow through the real detection path."""
        if faults is not None and not self.guard:
            raise ValueError("chaos injection requires guard=True — "
                             "injected faults must hit the real "
                             "finiteness check")
        self._faults = faults

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               deadline_ms: Optional[float] = None) -> Request:
        """Queue one request.  Tokens arrive in ``Request.generated`` as
        the service steps; the first token comes from prefill.  Raises
        :class:`QueueFull` when a bounded queue is at capacity —
        back-pressure is the caller's problem, unbounded growth is
        nobody's solution."""
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self._counters["rejected"].inc()
            raise QueueFull(
                f"admission queue full ({len(self._queue)} >= "
                f"max_queue={self.max_queue})",
                queue_depth=len(self._queue), max_queue=self.max_queue)
        prompt = np.asarray(prompt, np.int32).ravel()
        if prompt.size < 1 or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens >= 1")
        positions = int(prompt.size) + max_new_tokens - 1
        if self.paged and positions > self.max_seq:
            raise ValueError(
                f"request needs {positions} cache positions, service "
                f"max_seq is {self.max_seq}")
        req = Request(self._next_rid, prompt, max_new_tokens)
        req.t_submit = self._now()
        eff = deadline_ms if deadline_ms is not None else self.deadline_ms
        if eff is not None:
            req.deadline_s = req.t_submit + eff / 1e3
        self._next_rid += 1
        self._queue.append(req)
        self._requests[req.rid] = req
        self._counters["submitted"].inc()
        self._g_queue.set(len(self._queue))
        return req

    def cancel(self, rid: int) -> bool:
        """Cancel a live request: a queued one retires immediately, an
        active one is preempted (pages reclaimed) and retires.  Returns
        False for unknown or already-terminal rids."""
        req = self._requests.get(rid)
        if req is None or req.done:
            return False
        if req.slot >= 0:
            self._preempt(req.slot, requeue=False, status=ST_CANCELLED,
                          reason="cancel")
        else:
            self._queue.remove(req)
            self._retire(req, ST_CANCELLED)
        return True

    def step(self) -> bool:
        """One service tick: fire scheduled faults, sweep deadlines,
        admit whatever fits (conflict-round prefill), then one guarded
        continuous-batched decode over every active slot.  Returns True
        while any request is queued or in flight."""
        tick = self._counters["steps"].value
        before = (self._counters["admitted"].value,
                  self._counters["retired"].value)
        self._apply_faults(tick)
        self._sweep_deadlines()
        self._admit()
        slots = sorted(self._active)
        progressed = False
        if slots:
            # pages each slot's walk touches this tick (incl. the cell
            # being written) — what the kernel/bounded paths actually
            # read, and the honest work metric for the gather oracle too
            ps = self.pool.page_size
            pages = (sum(self._active[s].pos // ps + 1 for s in slots)
                     if self.paged else len(slots))
            tr = _trace.get_tracer()
            t0 = _trace.now()
            ok_slots = self._decode_tick(slots)
            self._counters["decode_items"].inc(len(slots))
            self._counters["pages_attended"].inc(pages)
            tok_h = np.asarray(self._tok)      # one sync per tick
            pos_h = np.asarray(self._pos)
            if tr.enabled:
                tr.event_span("serve.decode", t0, _trace.now(),
                              lane="engine", path=self.decode_path,
                              batch=len(slots), pages_attended=pages)
            for slot in ok_slots:
                req = self._active[slot]
                req.generated.append(int(tok_h[slot]))
                req.pos = int(pos_h[slot])
                self._counters["generated_tokens"].inc()
                progressed = True
            for slot in ok_slots:
                req = self._active[slot]
                if len(req.generated) >= req.max_new_tokens:
                    self._retire(req)
        self._counters["steps"].inc()
        self._sample_gauges()
        if (progressed
                or self._counters["admitted"].value > before[0]
                or self._counters["retired"].value > before[1]):
            self._last_progress_tick = tick
        return bool(self._active or self._queue)

    def run_until_complete(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.step():
                return
        raise ServiceStalled(
            f"service did not drain in {max_steps} steps: "
            f"{len(self._queue)} queued, {len(self._active)} active, "
            f"last progress at tick {self._last_progress_tick} of "
            f"{self._counters['steps'].value}",
            queue_depth=len(self._queue), active_slots=len(self._active),
            last_progress_tick=self._last_progress_tick,
            steps=self._counters["steps"].value)

    def compiled_entry_points(self) -> Dict[str, List]:
        """The service's module registry: which specialized entry points
        exist (prefill by (prompt length, batch size), decode by batch
        size)."""
        return {"prefill_plens": sorted({p for p, _ in self._prefill_fns}),
                "prefill_shapes": sorted(self._prefill_fns),
                "decode_batch_sizes": sorted(self.decode_batch_sizes_seen)}

    # -- fault application (chaos harness) -----------------------------------
    def _apply_faults(self, tick: int) -> None:
        if self._faults is None:
            return
        for ev in self._faults.events_at(tick):
            applied = True
            if ev.kind == "nan_decode":
                if self._active:
                    slots = sorted(self._active)
                    slot = slots[ev.victim % len(slots)]
                    self._poison_budget[slot] = max(
                        self._poison_budget.get(slot, 0), ev.sticky)
                else:
                    applied = False    # nothing decoding — fires as no-op
            elif ev.kind == "admission_fail":
                self._admission_fault = True
            elif ev.kind == "drop_prefill":
                self._prefill_fns.clear()
            elif ev.kind == "stall":
                self._skew += ev.skew_s
            if applied:
                self._counters["faults_injected"].inc()
            self.faults_fired.append((tick, ev, applied))

    def _arm_poison(self, slots: Sequence[int]) -> None:
        """Spend one round of each victim slot's poison budget by arming
        its guard flag to :data:`FLAG_POISON` — the jitted round tail
        NaNs the armed slots' logits (see ``_finish_decode``)."""
        if not self._poison_budget:
            return
        hit = [s for s in slots if self._poison_budget.get(s, 0) > 0]
        if not hit:
            return
        self._flags = self._flags.at[jnp.asarray(hit)].set(FLAG_POISON)
        for s in hit:
            self._poison_budget[s] -= 1
            if self._poison_budget[s] <= 0:
                del self._poison_budget[s]

    # -- deadlines -----------------------------------------------------------
    def _sweep_deadlines(self) -> None:
        now = self._now()
        expired_q = [r for r in self._queue
                     if r.deadline_s is not None and now >= r.deadline_s]
        for req in expired_q:
            self._queue.remove(req)
            self._retire(req, ST_DEADLINE)
        for slot in sorted(self._active):
            req = self._active[slot]
            if req.deadline_s is not None and now >= req.deadline_s:
                self._preempt(slot, requeue=False, status=ST_DEADLINE,
                              reason="deadline")

    # -- admission (conflict round + prefill family) -------------------------
    def _admit(self) -> int:
        batch: List[Request] = []
        while self._queue and self._free_slots:
            req = self._queue[0]
            need = self.pool.pages_needed(req.total_positions)
            if not self.pool.can_admit(need):
                break
            self._queue.popleft()
            req.t_admit = self._now()
            req.slot = self._free_slots.pop()
            req.pages = self.pool.alloc(need, owner=req.rid)
            batch.append(req)
        if not batch:
            return 0
        # lower the batch as a conflict round over canonical page
        # resources (single round + single coloring phase proven by
        # plan_admission), then execute the PREFILL family through the
        # rounds backend — run_one is the jitted prefill entry point
        try:
            if self._admission_fault:
                self._admission_fault = False
                raise AdmissionConflict("injected admission failure (chaos)")
            sched, plan = self.pool.plan_admission(
                [r.pages for r in batch], TT_PREFILL, datas=batch,
                nr_lanes=self.nr_lanes)
        except AdmissionConflict:
            # roll back: pages to the free list, slots returned, requests
            # requeued in arrival order — retried next tick.  The pool
            # must come out of the rollback conserving every page.
            for req in reversed(batch):
                self.pool.free(req.pages)
                req.pages = []
                self._free_slots.append(req.slot)
                req.slot = -1
                self._queue.appendleft(req)
            self.pool.check_invariants()
            self._counters["retries"].inc(len(batch))
            return 0
        run_plan(sched, self.registry, "rounds", plan=plan)
        self._counters["admitted"].inc(len(batch))
        for req in batch:
            req.status = "active"
            if len(req.generated) >= req.max_new_tokens:
                self._retire(req)      # prompt-only requests never decode
        return len(batch)

    def _run_prefill(self, tid: int, req: Request) -> None:
        self._prefill_group([req])

    def _run_prefill_batch(self, tids: Sequence[int],
                           reqs: Sequence[Request]) -> None:
        """Batched multi-request prefill: same-length feeds admitted in
        one conflict round share one jitted entry point (one forward pass
        over a ``(nb, plen)`` token block instead of nb B=1 calls)."""
        groups: Dict[int, List[Request]] = {}
        for req in reqs:
            groups.setdefault(len(req.feed_tokens()), []).append(req)
        for group in groups.values():
            self._prefill_group(group)

    def _prefill_group(self, reqs: List[Request]) -> None:
        feeds = [req.feed_tokens() for req in reqs]
        plen = int(feeds[0].size)
        nb = len(reqs)
        fn = self._prefill_fns.get((plen, nb))
        if fn is None:
            fn = self._prefill_fns[(plen, nb)] = self._make_prefill_fn(
                plen, nb)
        np_p = self.pool.pages_needed(plen)
        # only the first ceil(plen/ps) pages hold prompt positions; the
        # rest of each request's pages fill one decode-scatter at a time
        page_ids = np.zeros((nb, np_p), np.int32)
        pt_rows = np.zeros((nb, self.max_pages), np.int32)
        slots = np.zeros((nb,), np.int32)
        base_key = jax.random.PRNGKey(self.sampling.seed)
        req_keys = np.stack(
            [np.asarray(jax.random.fold_in(base_key, req.rid))
             for req in reqs])
        for i, req in enumerate(reqs):
            page_ids[i] = req.pages[:np_p]
            pt_rows[i, :len(req.pages)] = req.pages
            slots[i] = req.slot
        tokens = np.stack(feeds)
        (tok0, self.pool.leaves, self._pt, self._tok, self._pos,
         self._keys) = fn(
            self.params, jnp.asarray(tokens), self.pool.leaves,
            jnp.asarray(page_ids), jnp.asarray(pt_rows),
            jnp.asarray(slots), jnp.asarray(req_keys), self._pt,
            self._tok, self._pos, self._keys)
        tok0_h = np.asarray(tok0)
        t = self._now()                # prefill yields the next token
        for i, req in enumerate(reqs):
            req.generated.append(int(tok0_h[i]))
            req.pos = plen
            if not req.t_first:
                req.t_first = t
            self._active[req.slot] = req
            self._counters["generated_tokens"].inc()

    def _make_prefill_fn(self, plen: int, nb: int) -> Callable:
        cfg = self.cfg
        paged = self.paged
        ps = self.pool.page_size
        np_p = self.pool.pages_needed(plen)
        pad_to = np_p * ps - plen
        sampling = self.sampling

        @jax.jit
        def prefill_entry(params, tokens, leaves, page_ids, pt_rows,
                          slots, req_keys, pt, tok, pos, keys):
            logits, cache, _ = serving_mod.prefill(params, cfg, tokens)
            out = dict(leaves)
            if paged:
                for k, leaf in leaves.items():
                    c = cache[k]                     # (L, nb, plen, ...)
                    c = jnp.pad(c, [(0, 0), (0, 0), (0, pad_to)]
                                + [(0, 0)] * (c.ndim - 3))
                    c = c.reshape((c.shape[0], nb, np_p, ps) + c.shape[3:])
                    out[k] = leaf.at[:, page_ids].set(c.astype(leaf.dtype))
            else:
                for k, leaf in leaves.items():
                    out[k] = leaf.at[:, page_ids[:, 0]].set(
                        cache[k].astype(leaf.dtype))
            keys = keys.at[slots].set(req_keys)
            tok0, new_keys = serving_mod.sample_tokens(
                logits, keys[slots], sampling.temperature, sampling.top_k)
            if sampling.temperature > 0.0:
                keys = keys.at[slots].set(new_keys)
            return (tok0, out, pt.at[slots].set(pt_rows),
                    tok.at[slots].set(tok0), pos.at[slots].set(plen),
                    keys)

        return prefill_entry

    # -- decode (engine task family) -----------------------------------------
    def _decode_tick(self, slots: List[int]) -> List[int]:
        """One guarded decode round over ``slots``.  Runs the active
        ladder rung; with the guard on, reads the per-slot finiteness
        flags afterwards, retries any tripped slot once on the gather
        reference round function (restoring the slot's pre-round token /
        position / key from the immutable pre-round buffers), and
        preempts slots whose retry trips too.  Returns the slots whose
        tokens this tick are trustworthy."""
        prev = (self._tok, self._pos, self._keys)   # immutable snapshots
        self._arm_poison(slots)
        sched = self._decode_sched(slots)
        plan = lower(sched, self.nr_lanes)
        run_plan(sched, self.registry, "engine", plan=plan,
                 engine=self._hooks_by_path[self.decode_path_active])
        self.decode_batch_sizes_seen.add(len(slots))
        if not self.guard:
            return slots
        flags_h = np.asarray(self._flags)
        bad = [s for s in slots if flags_h[s] != FLAG_OK]
        if not bad:
            self._note_clean_tick()
            return slots
        # faulted round: the victims' token/position/key advanced with
        # garbage — restore from the pre-round arrays (zero-copy: jax
        # arrays are immutable) and re-run just those slots on the
        # reference path.  The faulted round's KV-cell writes need no
        # undo: the retry rewrites the victims' cells at the same
        # (page, offset), and decode masks everything beyond pos.
        self._counters["retries"].inc(len(bad))
        self.retried_rids.update(self._active[s].rid for s in bad)
        self._note_fault_tick()
        idx = jnp.asarray(bad)
        self._tok = self._tok.at[idx].set(prev[0][idx])
        self._pos = self._pos.at[idx].set(prev[1][idx])
        self._keys = self._keys.at[idx].set(prev[2][idx])
        self._arm_poison(bad)          # sticky faults poison the retry too
        rsched = self._decode_sched(bad)
        run_plan(rsched, self.registry, "engine",
                 plan=lower(rsched, self.nr_lanes),
                 engine=self._hooks_by_path[self._ladder[-1]])
        flags_h = np.asarray(self._flags)
        still_bad = [s for s in bad if flags_h[s] != FLAG_OK]
        for s in still_bad:
            # restore once more so the requeued request's host state is
            # consistent (its generated list never saw this tick)
            self._tok = self._tok.at[s].set(prev[0][s])
            self._pos = self._pos.at[s].set(prev[1][s])
            self._keys = self._keys.at[s].set(prev[2][s])
            self._preempt(s, requeue=True, reason="nan_decode")
        return [s for s in slots if s not in still_bad]

    def _note_fault_tick(self) -> None:
        """Degrade one rung with exponential backoff: each consecutive
        faulted tick doubles the clean-tick cooldown a rung must survive
        before promotion back up the ladder."""
        self._fault_streak += 1
        self._cooldown = min(2 ** self._fault_streak, 256)
        if self._level < len(self._ladder) - 1:
            self._level += 1
        self._g_level.set(self._level)

    def _note_clean_tick(self) -> None:
        if self._cooldown > 0:
            self._cooldown -= 1
            return
        if self._level > 0:
            self._level -= 1           # promote one rung per clean window
            self._g_level.set(self._level)
        else:
            self._fault_streak = 0

    def _decode_sched(self, slots: Sequence[int]) -> QSched:
        """Canonical decode graph: one DECODE task per active slot locking
        one state resource under a root.  The payload carries ``(slot,
        pos)`` — task *data* is excluded from the structural hash, so the
        plan cache key still depends only on the batch size even though
        positions change every tick."""
        s = QSched()
        root = s.addres()
        for slot in slots:
            rid = s.addres(parent=root)
            tid = s.addtask(type=TT_DECODE,
                            data=(int(slot), int(self._active[slot].pos)))
            s.addlock(tid, rid)
        return s

    def _encode_decode(self, tid: int, data: Tuple[int, int]):
        slot, pos = data
        return [(ENG_DECODE, int(slot), int(pos))]

    def _no_host_decode(self, tid: int, data) -> None:
        raise NotImplementedError(
            "the decode family is device-resident; run it through the "
            "'engine' backend")

    def _statics_for(self, path: str) -> Tuple:
        if path != "bounded":
            return (self.params,)
        # page-walk bound for this round, carried as the SHAPE of a dummy
        # static so the engine's jit cache re-specializes exactly when the
        # bound grows (descriptor *values* never retrace; shapes do)
        mx = max((r.pos for r in self._active.values()), default=0)
        n_walk = min(self.max_pages, mx // self.pool.page_size + 1)
        return (self.params, jnp.zeros((n_walk,), jnp.int32))

    def _statics(self) -> Tuple:
        return self._statics_for(self.decode_path_active)

    def _buffers(self) -> Tuple:
        return (self.pool.leaves, self._pt, self._tok, self._pos,
                self._keys, self._flags)

    def _writeback(self, buffers: Tuple) -> None:
        (self.pool.leaves, self._pt, self._tok, self._pos,
         self._keys, self._flags) = buffers

    def _sample_gauges(self) -> None:
        """Sample occupancy/depth gauges and, when a tracer is enabled,
        emit them as counter-track samples — the page-pool occupancy,
        queue-depth and failure-counter time series in the Perfetto
        view."""
        in_use = self.pool.allocated
        self._g_pages.set(in_use)
        self._g_queue.set(len(self._queue))
        self._g_active.set(len(self._active))
        tr = _trace.get_tracer()
        if tr.enabled:
            t = _trace.now()
            tr.counter("serve.pages_in_use", in_use, t=t)
            tr.counter("serve.queue_depth", len(self._queue), t=t)
            tr.counter("serve.active_slots", len(self._active), t=t)
            tr.counter("serve.pages_attended",
                       self._counters["pages_attended"].value, t=t)
            for k in ("preemptions", "retries", "rejected",
                      "deadline_exceeded"):
                tr.counter(f"serve.{k}", self._counters[k].value, t=t)

    # -- eviction / retirement -----------------------------------------------
    def _preempt(self, slot: int, *, requeue: bool, status: str = ST_DONE,
                 reason: str = "") -> None:
        """Evict the request occupying ``slot``: scatter the victim out
        of the device-resident engine buffers (its page-table row, token,
        position, key and guard flag are zeroed so a stale row can never
        alias a later tenant), return its pages to the pool free list
        with conservation asserted, then either requeue it for
        re-admission (the conflict model guarantees re-running its
        prefill later is order-safe) or retire it with ``status``."""
        req = self._active.pop(slot)
        t0 = self._now()
        self._pt = self._pt.at[slot].set(0)
        self._tok = self._tok.at[slot].set(0)
        self._pos = self._pos.at[slot].set(0)
        self._keys = self._keys.at[slot].set(0)
        self._flags = self._flags.at[slot].set(FLAG_OK)
        self.pool.free(req.pages)
        req.pages = []
        self.pool.check_invariants()   # page conservation, every eviction
        self._free_slots.append(slot)
        self._poison_budget.pop(slot, None)
        req.slot = -1
        req.pos = 0
        req.preemptions += 1
        self._counters["preemptions"].inc()
        self.faulted_rids.add(req.rid)
        tr = _trace.get_tracer()
        if tr.enabled:
            tr.event_span("request.preempted", t0, self._now(),
                          lane=f"req {req.rid}", process="requests",
                          rid=req.rid, reason=reason, requeue=requeue,
                          tokens_so_far=len(req.generated))
        if requeue:
            req.status = "queued"
            self._queue.appendleft(req)
            self._g_queue.set(len(self._queue))
        else:
            self._retire(req, status)

    def _retire(self, req: Request, status: str = ST_DONE) -> None:
        assert status in TERMINAL_STATES
        if req.pages:
            self.pool.free(req.pages)
            req.pages = []
        if req.slot >= 0:
            self._active.pop(req.slot, None)
            self._free_slots.append(req.slot)
            req.slot = -1
        req.status = status
        req.done = True
        req.t_done = self._now()
        if not req.t_first:            # never produced a token
            req.t_first = req.t_done
        self._requests.pop(req.rid, None)
        self._counters["retired"].inc()
        if status == ST_CANCELLED:
            self._counters["cancelled"].inc()
            self.faulted_rids.add(req.rid)
        elif status == ST_DEADLINE:
            self._counters["deadline_exceeded"].inc()
            self.faulted_rids.add(req.rid)
        self._h_ttft.observe(req.ttft_s)
        self._h_latency.observe(req.latency_s)
        tr = _trace.get_tracer()
        if tr.enabled:
            # request lifecycle as nested-looking phases on one lane per
            # request: queued (submit->admit), prefill (admit->first
            # token), decode (first token->retire).  Stages a request
            # never reached (cancelled in queue, expired before a token)
            # simply emit no span.
            lane = f"req {req.rid}"
            kw = dict(lane=lane, process="requests", rid=req.rid)

            def span(name, t0, t1, **extra):
                if t1 >= t0 > 0:
                    tr.event_span(name, t0, t1, **kw, **extra)

            span("request.queued", req.t_submit, req.t_admit or req.t_done)
            span("request.prefill", req.t_admit, req.t_first,
                 prompt_len=int(req.prompt.size))
            if req.t_done > req.t_first:
                span("request.decode", req.t_first, req.t_done,
                     tokens=len(req.generated))
            span("request", req.t_submit, req.t_done, status=status,
                 ttft_s=req.ttft_s, latency_s=req.latency_s)
