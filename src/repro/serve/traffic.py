"""Open-loop synthetic traffic for the serving benchmark.

Requests arrive on a Poisson process measured in *service steps* (one
step = one continuous-batched decode tick), independent of service
progress — the open-loop discipline that exposes queueing behaviour a
closed loop hides.  Prompt contents are uniform random token ids;
lengths and generation budgets are drawn from caller-supplied choices so
the stream is ragged (the regime where continuous batching beats the
static-batch loop, which must decode every batch to its slowest member).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np


@dataclass(frozen=True)
class SyntheticRequest:
    arrival_step: int
    prompt: np.ndarray            # (plen,) int32
    max_new_tokens: int


def open_loop_trace(n_requests: int, *, mean_interarrival: float,
                    prompt_lens: Sequence[int],
                    new_token_lens: Sequence[int],
                    vocab_size: int, seed: int = 0,
                    ) -> List[SyntheticRequest]:
    """Draw ``n_requests`` arrivals: exponential inter-arrival gaps of
    mean ``mean_interarrival`` steps (0 = all arrive up front), prompt
    length and ``max_new_tokens`` sampled uniformly from the given
    choices.  Deterministic per seed."""
    if n_requests < 1:
        raise ValueError("need at least one request")
    rng = np.random.default_rng(seed)
    trace: List[SyntheticRequest] = []
    t = 0.0
    for _ in range(n_requests):
        if mean_interarrival > 0:
            t += rng.exponential(mean_interarrival)
        plen = int(rng.choice(np.asarray(prompt_lens)))
        n_new = int(rng.choice(np.asarray(new_token_lens)))
        prompt = rng.integers(0, vocab_size, size=plen, dtype=np.int32)
        trace.append(SyntheticRequest(int(t), prompt, n_new))
    return trace


def replay(service, trace: Sequence[SyntheticRequest],
           max_steps: int = 100_000, faults=None) -> List:
    """Feed a trace into a :class:`~repro.serve.service.GenerateService`
    open-loop: submit every request whose arrival step has passed, tick
    once, repeat until drained.  Returns the submitted Request handles in
    arrival order.

    ``faults`` installs a :class:`~repro.serve.faults.FaultPlan` on the
    service for the replay — the chaos harness's entry point for
    trace-level tests and the CI chaos smoke.  A bounded-queue service
    that rejects an arrival propagates :class:`QueueFull` to the caller
    (open-loop traffic does not retry); a replay that fails to drain
    raises the service's diagnostic :class:`ServiceStalled`."""
    from .service import ServiceStalled

    if faults is not None:
        service.inject(faults)
    pending = sorted(trace, key=lambda r: r.arrival_step)
    handles, i = [], 0
    for step in range(max_steps):
        while i < len(pending) and pending[i].arrival_step <= step:
            handles.append(service.submit(pending[i].prompt,
                                          pending[i].max_new_tokens))
            i += 1
        busy = service.step()
        if i == len(pending) and not busy:
            return handles
    raise ServiceStalled(
        f"trace did not drain in {max_steps} steps",
        queue_depth=len(service._queue),
        active_slots=len(service._active),
        last_progress_tick=service._last_progress_tick,
        steps=max_steps)
