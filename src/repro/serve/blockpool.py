"""Paged KV-cache block pool: cache pages as lockable QuickSched resources.

The serving tier's memory is a fixed pool of ``n_pages`` cache pages, each
holding ``page_size`` token positions of every layer's KV state (attention
families) or one request's whole recurrent state (SSM — O(1) in sequence
length, one "page" per live request).  Requests own disjoint page sets
tracked by a free-list allocator; pages return to the free list at
retirement and are reused by later requests (the exllamav3 block-pool
idiom).  Stale contents of a reused page are harmless by construction:
decode masks every position strictly beyond ``pos``, so a page is
overwritten before it is ever read (asserted bit-exactly in
``tests/test_serve.py``).

Admission *is* a QuickSched conflict problem (DESIGN.md §Serving).  Every
page is registered as a hierarchical resource — root → bank → page — in a
persistent ``core.graph.QSched`` forest, and each admission batch lowers
through ``core.plan.lower`` as one task per request locking its assigned
pages.  A correct allocator yields a single conflict-free round; a
double-assigned page makes two tasks lock the same resource and the
planner is *forced* to split them into separate rounds, which
:meth:`BlockPool.plan_admission` reports as :class:`AdmissionConflict`.
The write-coloring pass (``core.plan.color_phases``) over the physical
page-id write sets is the independent safety proof: a conflict-free
admission round colors to exactly one phase.

So the plan cache can serve as the compiled-module registry (identical
batch shapes must produce identical structural hashes), admission graphs
are built over *canonical* resources: physical page ids are relabelled in
first-use order.  Relabelling is injective on distinct pages, so a
double assignment still collides after relabelling — canonicalisation
never masks a real conflict (property-tested in
``tests/test_blockpool_properties.py``).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.graph import QSched
from repro.core.plan import ExecutionPlan, color_phases, lower

# Task type used for admission/prefill tasks in the serving registry
# (``serve.service`` executes them through the ``rounds`` backend).
TT_PREFILL = 0


class AdmissionConflict(RuntimeError):
    """The planner refused to admit a batch in one conflict-free round —
    i.e. the allocator handed the same page to two live requests."""


class BlockPool:
    """Free-list page allocator over a paged device cache.

    ``cfg`` is optional: without it the pool is a pure allocator +
    admission planner (what the property suite drives); with it the pool
    also owns the paged cache leaves — ``serving.init_cache`` evaluated at
    ``batch=n_pages, max_seq=page_size``, so every leaf's second axis is
    the page id:

    * attention families (dense/moe incl. MLA): seq-paged leaves
      ``(L, n_pages, page_size, ...)``;
    * ssm: per-request state leaves ``(L, n_pages, ...)`` — a "page" is a
      whole state slot and every request holds exactly one.
    """

    def __init__(self, n_pages: int, page_size: int, cfg: Any = None,
                 bank_size: int = 8):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError("n_pages and page_size must be positive")
        self.n_pages = n_pages
        self.page_size = page_size
        self.cfg = cfg
        self.paged = cfg is None or cfg.family != "ssm"
        self.leaves: Optional[Dict[str, Any]] = None
        if cfg is not None:
            from repro.models import serving
            self.leaves = serving.init_cache(cfg, batch=n_pages,
                                             max_seq=page_size)

        # persistent hierarchical resource forest (paper §3.2): pool root
        # → banks → pages.  ``page_res[p]`` is page p's resource id; the
        # forest is what tests/DESIGN point at when they say "pages are
        # resources", and bank-level locks are where whole-region
        # operations (defrag/flush) would attach.
        self.sched = QSched()
        self.root_res = self.sched.addres()
        self.bank_res: List[int] = []
        self.page_res: List[int] = []
        for p in range(n_pages):
            if p % bank_size == 0:
                self.bank_res.append(self.sched.addres(parent=self.root_res))
            self.page_res.append(self.sched.addres(parent=self.bank_res[-1]))

        # LIFO free list: most-recently-freed pages are re-allocated first
        # (hottest reuse), owners maps page -> live owner key
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._owner: List[Optional[Any]] = [None] * n_pages

    # -- free-list allocator -------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> int:
        return self.n_pages - len(self._free)

    def owner_of(self, page: int) -> Optional[Any]:
        return self._owner[page]

    def pages_needed(self, n_positions: int) -> int:
        """Pages one request needs for ``n_positions`` cache positions —
        ``ceil(n/page_size)`` for seq-paged families, always 1 for O(1)
        recurrent state."""
        if not self.paged:
            return 1
        return max(1, -(-int(n_positions) // self.page_size))

    def can_admit(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    def alloc(self, n_pages: int, owner: Any) -> List[int]:
        """Pop ``n_pages`` pages off the free list for ``owner``."""
        if owner is None:
            raise ValueError("alloc: owner must not be None")
        if n_pages > len(self._free):
            raise MemoryError(
                f"block pool exhausted: want {n_pages} pages, "
                f"{len(self._free)} free of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n_pages)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def free(self, pages: Sequence[int]) -> None:
        """Return pages to the free list (request retirement/eviction)."""
        for p in pages:
            if self._owner[p] is None:
                raise ValueError(f"free: page {p} is not allocated")
            self._owner[p] = None
            self._free.append(p)

    def check_invariants(self) -> None:
        """Free-list conservation + ownership disjointness — the pool's
        corruption tripwire (the hypothesis suite calls this after every
        operation)."""
        if self.allocated + self.free_count != self.n_pages:
            raise AssertionError(
                f"page conservation violated: {self.allocated} allocated + "
                f"{self.free_count} free != {self.n_pages}")
        if len(set(self._free)) != len(self._free):
            raise AssertionError("free list holds a duplicate page")
        for p in self._free:
            if self._owner[p] is not None:
                raise AssertionError(f"page {p} is free but owned")

    # -- admission as a conflict problem -------------------------------------
    def admission_sched(self, assignments: Sequence[Sequence[int]],
                        task_type: int = TT_PREFILL,
                        datas: Optional[Sequence[Any]] = None,
                        ) -> Tuple[QSched, List[Tuple[Tuple, Tuple]]]:
        """Build the admission graph for one batch: task ``i`` locks the
        canonical resources of ``assignments[i]`` (physical page ids
        relabelled in first-use order under a root resource, so equal batch
        shapes hash equally and the plan cache hits).  Also returns the
        physical ``(reads, writes)`` access list for ``color_phases`` —
        the write sets are the *un*-relabelled page ids, keeping the
        coloring proof independent of the canonicalisation."""
        s = QSched()
        root = s.addres()
        canon: Dict[int, int] = {}
        accesses: List[Tuple[Tuple, Tuple]] = []
        for i, pages in enumerate(assignments):
            tid = s.addtask(type=task_type,
                            data=None if datas is None else datas[i])
            for p in pages:
                rid = canon.get(p)
                if rid is None:
                    rid = canon[p] = s.addres(parent=root)
                s.addlock(tid, rid)
            accesses.append(((), tuple(pages)))
        return s, accesses

    def plan_admission(self, assignments: Sequence[Sequence[int]],
                       task_type: int = TT_PREFILL,
                       datas: Optional[Sequence[Any]] = None,
                       nr_lanes: int = 1,
                       ) -> Tuple[QSched, ExecutionPlan]:
        """Lower one admission batch and prove it safe: the plan must be a
        single conflict-free round AND the write coloring over physical
        page ids must produce at most one phase.  Raises
        :class:`AdmissionConflict` otherwise (an allocator bug — never
        reachable through :meth:`alloc`, property-tested)."""
        sched, accesses = self.admission_sched(assignments, task_type, datas)
        plan = lower(sched, nr_lanes)
        if plan.nr_rounds != 1:
            raise AdmissionConflict(
                f"admission batch needs {plan.nr_rounds} rounds — a page is "
                f"assigned to two requests")
        bounds = color_phases(accesses)
        if len(bounds) - 1 > 1:
            raise AdmissionConflict(
                f"write coloring split the admission round into "
                f"{len(bounds) - 1} phases — overlapping page write sets")
        return sched, plan
