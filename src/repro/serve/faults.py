"""Deterministic chaos injection for the serving tier.

Fault tolerance that is only exercised by real failures is fault
tolerance that has never been tested.  This module makes every failure
path in :class:`~repro.serve.service.GenerateService` *reproducibly*
reachable: a :class:`FaultPlan` is a static, seeded schedule of
:class:`FaultEvent`\\ s keyed by service tick, and the service consumes it
at the top of every :meth:`step` — no wall-clock randomness, no
monkeypatching, the same plan against the same trace fires the same
faults at the same points in the request stream every run (the
conformance suite in ``tests/test_faults.py`` depends on exactly this to
assert that *unaffected* requests' token streams are bitwise-identical
to a fault-free replay).

Four fault kinds, one per recovery path (DESIGN.md §Robustness):

* ``nan_decode`` — NaN-poison a decode round's logits for one victim
  slot (``sticky`` consecutive decode executions, retries included).
  ``sticky=1`` models a transient compute fault: the post-round
  finiteness guard trips, the in-tick retry on the ``gather`` reference
  round function recomputes cleanly, the stream is unharmed.
  ``sticky>=2`` poisons the retry too, forcing preemption: pages are
  reclaimed and the request is re-admitted through the normal prefill
  family — order-safe because conflicting tasks may run in any order,
  just not concurrently (the paper's central invariant).
* ``admission_fail`` — the next admission attempt fails *after* pages
  and slots are assigned, exercising the rollback path (pages freed,
  slots returned, requests requeued in arrival order, conservation
  asserted).
* ``drop_prefill`` — drop the prefill entry-point cache (the service's
  compiled-module registry), exercising cold re-specialization
  mid-stream.
* ``stall`` — jump the service's virtual clock by ``skew_s`` seconds,
  as if a tick stalled that long: every in-flight deadline that the jump
  passes expires on the next sweep (``DEADLINE_EXCEEDED``), without the
  test suite ever sleeping.

Injection is honest: ``nan_decode`` plants real NaNs in the logits
*inside* the jitted round function (via the poison lane of the guard
flags buffer), so detection flows through the same finiteness check that
would catch an organic NaN — the harness never short-circuits the guard
it is testing.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("nan_decode", "admission_fail", "drop_prefill", "stall")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``tick`` is the service step counter value
    at which it fires.  ``victim`` selects the target of a ``nan_decode``
    as an index into the sorted active slots at fire time (taken modulo
    the number of active slots, so seeded plans need no knowledge of the
    admission trajectory); ``sticky`` is how many consecutive decode
    executions of that slot stay poisoned (in-tick retries count — 1
    recovers via retry, >=2 forces preemption).  ``skew_s`` is the
    virtual-clock jump of a ``stall``."""
    tick: int
    kind: str
    victim: int = 0
    sticky: int = 1
    skew_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.tick < 0 or self.sticky < 1 or self.skew_s < 0:
            raise ValueError(f"malformed fault event {self!r}")


class FaultPlan:
    """An immutable schedule of fault events, indexable by tick.

    Build one explicitly from events (tests pin exact scenarios) or with
    :meth:`seeded` (CI chaos smoke: a Poisson sprinkling of every kind,
    deterministic per seed).  The service records what actually fired in
    ``GenerateService.faults_fired`` — a plan is a *schedule*, and e.g. a
    ``nan_decode`` scheduled while no slot is active fires as a no-op."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        evs = sorted(events, key=lambda e: (e.tick, e.kind, e.victim))
        self.events: Tuple[FaultEvent, ...] = tuple(evs)
        self._by_tick: Dict[int, List[FaultEvent]] = {}
        for e in self.events:
            self._by_tick.setdefault(e.tick, []).append(e)

    def __len__(self) -> int:
        return len(self.events)

    def events_at(self, tick: int) -> Tuple[FaultEvent, ...]:
        return tuple(self._by_tick.get(tick, ()))

    @property
    def last_tick(self) -> int:
        return self.events[-1].tick if self.events else -1

    def summary(self) -> Dict[str, int]:
        out = {k: 0 for k in FAULT_KINDS}
        for e in self.events:
            out[e.kind] += 1
        return out

    @classmethod
    def seeded(cls, seed: int, n_ticks: int, *,
               p_nan: float = 0.08, p_admission: float = 0.04,
               p_drop: float = 0.02, p_stall: float = 0.0,
               stall_skew_s: float = 0.0,
               sticky_choices: Sequence[int] = (1, 1, 3)) -> "FaultPlan":
        """Draw an independent Bernoulli per kind per tick (deterministic
        per seed).  ``sticky_choices`` biases ``nan_decode`` toward
        transient faults (retry recovers) with an occasional persistent
        one (preemption + re-admission).  ``p_stall`` only matters with a
        positive ``stall_skew_s`` and deadlines configured."""
        if n_ticks < 1:
            raise ValueError("n_ticks must be >= 1")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for t in range(n_ticks):
            if rng.random() < p_nan:
                events.append(FaultEvent(
                    t, "nan_decode", victim=int(rng.integers(0, 1 << 16)),
                    sticky=int(rng.choice(np.asarray(sticky_choices)))))
            if rng.random() < p_admission:
                events.append(FaultEvent(t, "admission_fail"))
            if rng.random() < p_drop:
                events.append(FaultEvent(t, "drop_prefill"))
            if p_stall > 0 and stall_skew_s > 0 and rng.random() < p_stall:
                events.append(FaultEvent(t, "stall", skew_s=stall_skew_s))
        return cls(events)
