"""Numerical executor for a synthesized pipeline schedule.

Runs the (stage, microbatch) tasks in the schedule's global time order —
forwards store VJP closures, backwards propagate cotangents and accumulate
per-stage gradients *in whatever order the conflict resolution chose* (the
accumulation is order-independent, which is exactly why it is modelled as a
QuickSched conflict and not a dependency chain).  The result must equal the
single-shot ``jax.grad`` of the unpipelined loss (tested).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .qsched_pipeline import PipelineSchedule


def pipelined_value_and_grad(
        stage_fns: Sequence[Callable],
        loss_fn: Callable,
        stage_params: Sequence[Any],
        microbatches: Sequence[Any],
        schedule: PipelineSchedule,
) -> Tuple[jnp.ndarray, List[Any]]:
    """stage_fns[k](params_k, x) -> y;  loss_fn(y_last, micro_batch) -> loss
    (mean-reduced over the microbatch).  Returns (total loss, grads per
    stage averaged over microbatches)."""
    S, M = schedule.n_stages, schedule.n_micro
    assert len(stage_fns) == S and len(microbatches) == M

    # merge lanes into global time order (the schedule's interleaving)
    events = []
    for lane in schedule.lanes:
        events.extend(lane)
    events.sort(key=lambda e: (e[3], e[1]))

    acts: Dict[Tuple[int, int], Any] = {}      # (stage, micro) -> input
    vjps: Dict[Tuple[int, int], Any] = {}
    cots: Dict[Tuple[int, int], Any] = {}      # cotangent flowing backward
    grads: List[Any] = [jax.tree.map(jnp.zeros_like, p)
                        for p in stage_params]
    losses = []

    for kind, k, m, t0, t1 in events:
        if kind == "F":
            x = microbatches[m]["x"] if k == 0 else acts[k, m]
            y, vjp = jax.vjp(stage_fns[k], stage_params[k], x)
            vjps[k, m] = vjp
            if k + 1 < S:
                acts[k + 1, m] = y
            else:
                loss, loss_vjp = jax.vjp(
                    lambda yy: loss_fn(yy, microbatches[m]), y)
                losses.append(loss)
                cots[k, m] = loss_vjp(jnp.ones_like(loss))[0]
        elif kind == "B":
            gp, gx = vjps[k, m](cots[k, m])
            # conflict-protected accumulation (any order)
            grads[k] = jax.tree.map(jnp.add, grads[k], gp)
            if k > 0:
                cots[k - 1, m] = gx
        # "U" tasks would apply the optimizer; the caller does that.

    loss = sum(losses) / M
    grads = [jax.tree.map(lambda g: g / M, gk) for gk in grads]
    return loss, grads
