"""Numerical executor for a synthesized pipeline schedule.

Runs the (stage, microbatch) tasks in schedule order — forwards store VJP
closures, backwards propagate cotangents and accumulate per-stage gradients
*in whatever order the conflict resolution chose* (the accumulation is
order-independent, which is exactly why it is modelled as a QuickSched
conflict and not a dependency chain).  The result must equal the
single-shot ``jax.grad`` of the unpipelined loss (tested).

Two drivers share the same task bodies (``_PipeRunner``):

* ``pipelined_value_and_grad``       — replays a discrete-event
  ``PipelineSchedule`` in global time order;
* ``pipelined_value_and_grad_plan``  — executes the shared ExecutionPlan
  lowering (``lower_pipeline_plan``) on any registered execution backend
  (``core.backends``).  ``rounds`` runs one conflict-free round per
  bulk-synchronous pipeline step on the host; ``sequential``/``threaded``
  drain the scheduler directly; ``engine`` lowers the F/B/U tasks to
  descriptor tables and runs the whole value-and-grad step as ONE jitted
  dispatch of the pipeline megakernel (DESIGN.md §Engine) — kernel-resident
  state is the stacked stage-activation and grad-accumulation slabs.
  Repeated calls with the same (S, M, costs) hit the plan cache and skip
  re-lowering.

The ``engine`` backend implements the *canonical uniform dense family*:
every stage is :func:`dense_stage` (``tanh(x @ w + b)``, square ``(D, D)``
weights), the loss is :func:`mse_loss`, and every microbatch is a
``(Bt, D)`` slab.  ``supports()`` discovers the capability from the
arguments — anything else raises :class:`~repro.core.BackendUnsupported`
instead of silently computing the wrong family.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import (BackendUnsupported, BatchSpec, EngineHooks,
                        get_backend, run_plan)

from .qsched_pipeline import B, F, U, PipelineSchedule, lower_pipeline_plan


def dense_stage(p, x):
    """The canonical uniform dense pipeline stage: ``tanh(x @ w + b)``.
    This is the stage family the engine megakernel implements in-kernel;
    passing it (by identity) is what makes a pipeline engine-eligible."""
    return jnp.tanh(x @ p["w"] + p["b"])


def mse_loss(y, mb):
    """Canonical microbatch loss: ``mean((y - mb['y'])**2)``."""
    return jnp.mean((y - mb["y"]) ** 2)


class _PipeRunner:
    """Holds pipeline state and executes F/B task bodies by (stage, micro)."""

    def __init__(self, stage_fns: Sequence[Callable], loss_fn: Callable,
                 stage_params: Sequence[Any], microbatches: Sequence[Any]):
        self.stage_fns = stage_fns
        self.loss_fn = loss_fn
        self.params = stage_params
        self.micro = microbatches
        self.S = len(stage_fns)
        self.M = len(microbatches)
        self.acts: Dict[Tuple[int, int], Any] = {}   # (stage, micro) -> input
        self.vjps: Dict[Tuple[int, int], Any] = {}
        self.cots: Dict[Tuple[int, int], Any] = {}   # cotangent flowing back
        self.grads: List[Any] = [jax.tree.map(jnp.zeros_like, p)
                                 for p in stage_params]
        self.losses: List[Any] = []

    def forward(self, k: int, m: int) -> None:
        x = self.micro[m]["x"] if k == 0 else self.acts[k, m]
        y, vjp = jax.vjp(self.stage_fns[k], self.params[k], x)
        self.vjps[k, m] = vjp
        if k + 1 < self.S:
            self.acts[k + 1, m] = y
        else:
            loss, loss_vjp = jax.vjp(
                lambda yy: self.loss_fn(yy, self.micro[m]), y)
            self.losses.append(loss)
            self.cots[k, m] = loss_vjp(jnp.ones_like(loss))[0]

    def backward(self, k: int, m: int) -> None:
        gp, gx = self.vjps[k, m](self.cots[k, m])
        # conflict-protected accumulation (any order)
        self.grads[k] = jax.tree.map(jnp.add, self.grads[k], gp)
        if k > 0:
            self.cots[k - 1, m] = gx

    def finish(self) -> Tuple[jnp.ndarray, List[Any]]:
        loss = sum(self.losses) / self.M
        grads = [jax.tree.map(lambda g: g / self.M, gk) for gk in self.grads]
        return loss, grads

    def registry(self) -> Mapping[int, BatchSpec]:
        """BatchSpecs for the F/B/U family: host bodies (``run_one``) plus
        the device descriptor encoders (``encode``) the engine backend
        lowers through.  Rows: [etype, stage, micro, in_slot, out_slot,
        first, last] — slots are flat stage·M + micro indices into the
        stacked activation/cotangent slabs; ``in_slot`` points at the
        previous stage's slab and degrades to the row's own (safe) slot on
        stage 0, where the kernel predicates it away."""
        S, M = self.S, self.M

        def enc_f(tid, d):
            _, k, m = d
            return [(engine.PIPE_F, k, m,
                     (k - 1) * M + m if k > 0 else k * M + m, k * M + m,
                     1 if k == 0 else 0, 1 if k == S - 1 else 0)]

        def enc_b(tid, d):
            _, k, m = d
            return [(engine.PIPE_B, k, m,
                     (k - 1) * M + m if k > 0 else k * M + m, k * M + m,
                     1 if k == 0 else 0, 0)]

        def enc_u(tid, d):
            return [(engine.PIPE_U, d[1], 0, 0, 0, 0, 0)]

        return {
            F: BatchSpec(run_one=lambda tid, d: self.forward(d[1], d[2]),
                         encode=enc_f),
            B: BatchSpec(run_one=lambda tid, d: self.backward(d[1], d[2]),
                         encode=enc_b),
            # U applies the optimizer — the CALLER's contract (see
            # pipelined_value_and_grad); on the host it is a no-op, in the
            # engine its branch performs the 1/M microbatch averaging.
            U: BatchSpec(run_one=lambda tid, d: None, encode=enc_u),
        }


def pipelined_value_and_grad(
        stage_fns: Sequence[Callable],
        loss_fn: Callable,
        stage_params: Sequence[Any],
        microbatches: Sequence[Any],
        schedule: PipelineSchedule,
) -> Tuple[jnp.ndarray, List[Any]]:
    """stage_fns[k](params_k, x) -> y;  loss_fn(y_last, micro_batch) -> loss
    (mean-reduced over the microbatch).  Returns (total loss, grads per
    stage averaged over microbatches).

    Event-kind contract: ``"F"`` and ``"B"`` execute the forward/backward
    bodies; ``"U"`` (weight update) is a deliberate no-op here — this
    function computes value-and-grad only, and *applying* the returned
    gradients (optimizer step) is the caller's responsibility.  Any other
    event kind is a schedule-synthesis bug and raises ``ValueError``
    instead of being silently skipped."""
    S, M = schedule.n_stages, schedule.n_micro
    assert len(stage_fns) == S and len(microbatches) == M
    runner = _PipeRunner(stage_fns, loss_fn, stage_params, microbatches)

    # merge lanes into global time order (the schedule's interleaving)
    events = []
    for lane in schedule.lanes:
        events.extend(lane)
    events.sort(key=lambda e: (e[3], e[1]))

    for kind, k, m, t0, t1 in events:
        if kind == "F":
            runner.forward(k, m)
        elif kind == "B":
            runner.backward(k, m)
        elif kind != "U":
            raise ValueError(
                f"unknown pipeline event kind {kind!r} (expected F/B/U)")
    return runner.finish()


def _engine_family(stage_fns, loss_fn, stage_params, microbatches):
    """Return (S, M, Bt, D) when the canonical dense family applies —
    every stage IS ``dense_stage``, the loss IS ``mse_loss``, and all
    parameter/microbatch shapes are uniform — else None.  This is the
    capability probe behind ``engine``-backend ``supports()``."""
    if not stage_fns or not microbatches:
        return None
    if len(stage_params) != len(stage_fns):
        return None
    if any(f is not dense_stage for f in stage_fns) or loss_fn is not mse_loss:
        return None
    try:
        pshapes = [(tuple(p["w"].shape), tuple(p["b"].shape))
                   for p in stage_params]
        mshapes = [(tuple(mb["x"].shape), tuple(mb["y"].shape))
                   for mb in microbatches]
    except (TypeError, KeyError, AttributeError):
        return None
    dim = pshapes[0][0][-1]
    if any(w != (dim, dim) or b != (dim,) for w, b in pshapes):
        return None
    bt = mshapes[0][0][0]
    if any(x != (bt, dim) or y != (bt, dim) for x, y in mshapes):
        return None
    return len(stage_fns), len(microbatches), bt, dim


def _engine_hooks(stage_params, microbatches, fam, out_box) -> EngineHooks:
    """EngineHooks for the canonical dense pipeline family: stack the
    stage parameters and microbatches as device statics, allocate the
    kernel-resident activation/cotangent/grad/loss slabs, and on
    writeback deliver ``(loss, grads)`` — the U branch already applied
    the 1/M averaging in-kernel, so writeback only sums the per-micro
    losses."""
    S, M, bt, dim = fam

    def statics():
        w = jnp.stack([jnp.asarray(p["w"], jnp.float32)
                       for p in stage_params])
        b = jnp.stack([jnp.asarray(p["b"], jnp.float32)
                       for p in stage_params])
        x = jnp.stack([jnp.asarray(mb["x"], jnp.float32)
                       for mb in microbatches])
        y = jnp.stack([jnp.asarray(mb["y"], jnp.float32)
                       for mb in microbatches])
        return w, b, x, y

    def buffers():
        return (jnp.zeros((S * M, bt, dim), jnp.float32),
                jnp.zeros((S * M, bt, dim), jnp.float32),
                jnp.zeros((S, dim, dim), jnp.float32),
                jnp.zeros((S, dim), jnp.float32),
                jnp.zeros((M, 1), jnp.float32))

    def writeback(out):
        _acts, _cots, gw, gb, loss = out
        out_box["loss"] = jnp.sum(loss) / M
        out_box["grads"] = [{"w": gw[k], "b": gb[k]} for k in range(S)]

    return EngineHooks(
        arg_width=engine.PIPE_ARG_WIDTH,
        round_fn=engine.pipe_round_fn(1.0 / M), statics=statics,
        buffers=buffers, writeback=writeback,
        row_access=engine.pipe_row_access)


def pipelined_value_and_grad_plan(
        stage_fns: Sequence[Callable],
        loss_fn: Callable,
        stage_params: Sequence[Any],
        microbatches: Sequence[Any],
        fwd_cost: float = 1.0,
        bwd_cost: float = 2.0,
        upd_cost: float = 0.5,
        per_stage_window: bool = True,
        mode: str = "rounds",
) -> Tuple[jnp.ndarray, List[Any]]:
    """Same computation, driven by the shared ExecutionPlan lowering on
    any registered execution backend (``mode``).  ``rounds``: each plan
    round is one bulk-synchronous pipeline step.  ``engine``: the whole
    value-and-grad step is ONE jitted dispatch of the pipeline megakernel
    (canonical dense family only — see module docstring); gradients and
    the microbatch-averaged loss come back from the device grad slabs."""
    runner = _PipeRunner(stage_fns, loss_fn, stage_params, microbatches)
    sched, _meta, plan = lower_pipeline_plan(
        runner.S, runner.M, fwd_cost, bwd_cost, upd_cost,
        per_stage_window=per_stage_window)
    registry = runner.registry()
    if get_backend(mode).device_resident:
        fam = _engine_family(stage_fns, loss_fn, stage_params, microbatches)
        if fam is None:
            raise BackendUnsupported(
                "the engine backend implements the canonical dense pipeline "
                "family only: dense_stage stages, mse_loss loss, uniform "
                "(Bt, D) microbatches and (D, D) stage weights")
        box: Dict[str, Any] = {}
        run_plan(sched, registry, mode, nr_workers=runner.S,
                 engine=_engine_hooks(stage_params, microbatches, fam, box),
                 plan=plan)
        return box["loss"], box["grads"]
    run_plan(sched, registry, mode, nr_workers=runner.S, plan=plan)
    return runner.finish()
