"""Numerical executor for a synthesized pipeline schedule.

Runs the (stage, microbatch) tasks in schedule order — forwards store VJP
closures, backwards propagate cotangents and accumulate per-stage gradients
*in whatever order the conflict resolution chose* (the accumulation is
order-independent, which is exactly why it is modelled as a QuickSched
conflict and not a dependency chain).  The result must equal the
single-shot ``jax.grad`` of the unpipelined loss (tested).

Two drivers share the same task bodies (``_PipeRunner``):

* ``pipelined_value_and_grad``       — replays a discrete-event
  ``PipelineSchedule`` in global time order;
* ``pipelined_value_and_grad_plan``  — executes the shared ExecutionPlan
  lowering (``lower_pipeline_plan``) through a BatchSpec registry, one
  conflict-free round per bulk-synchronous pipeline step.  Repeated calls
  with the same (S, M, costs) hit the plan cache and skip re-lowering.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import BatchSpec

from .qsched_pipeline import B, F, U, PipelineSchedule, lower_pipeline_plan


class _PipeRunner:
    """Holds pipeline state and executes F/B task bodies by (stage, micro)."""

    def __init__(self, stage_fns: Sequence[Callable], loss_fn: Callable,
                 stage_params: Sequence[Any], microbatches: Sequence[Any]):
        self.stage_fns = stage_fns
        self.loss_fn = loss_fn
        self.params = stage_params
        self.micro = microbatches
        self.S = len(stage_fns)
        self.M = len(microbatches)
        self.acts: Dict[Tuple[int, int], Any] = {}   # (stage, micro) -> input
        self.vjps: Dict[Tuple[int, int], Any] = {}
        self.cots: Dict[Tuple[int, int], Any] = {}   # cotangent flowing back
        self.grads: List[Any] = [jax.tree.map(jnp.zeros_like, p)
                                 for p in stage_params]
        self.losses: List[Any] = []

    def forward(self, k: int, m: int) -> None:
        x = self.micro[m]["x"] if k == 0 else self.acts[k, m]
        y, vjp = jax.vjp(self.stage_fns[k], self.params[k], x)
        self.vjps[k, m] = vjp
        if k + 1 < self.S:
            self.acts[k + 1, m] = y
        else:
            loss, loss_vjp = jax.vjp(
                lambda yy: self.loss_fn(yy, self.micro[m]), y)
            self.losses.append(loss)
            self.cots[k, m] = loss_vjp(jnp.ones_like(loss))[0]

    def backward(self, k: int, m: int) -> None:
        gp, gx = self.vjps[k, m](self.cots[k, m])
        # conflict-protected accumulation (any order)
        self.grads[k] = jax.tree.map(jnp.add, self.grads[k], gp)
        if k > 0:
            self.cots[k - 1, m] = gx

    def finish(self) -> Tuple[jnp.ndarray, List[Any]]:
        loss = sum(self.losses) / self.M
        grads = [jax.tree.map(lambda g: g / self.M, gk) for gk in self.grads]
        return loss, grads


def pipelined_value_and_grad(
        stage_fns: Sequence[Callable],
        loss_fn: Callable,
        stage_params: Sequence[Any],
        microbatches: Sequence[Any],
        schedule: PipelineSchedule,
) -> Tuple[jnp.ndarray, List[Any]]:
    """stage_fns[k](params_k, x) -> y;  loss_fn(y_last, micro_batch) -> loss
    (mean-reduced over the microbatch).  Returns (total loss, grads per
    stage averaged over microbatches)."""
    S, M = schedule.n_stages, schedule.n_micro
    assert len(stage_fns) == S and len(microbatches) == M
    runner = _PipeRunner(stage_fns, loss_fn, stage_params, microbatches)

    # merge lanes into global time order (the schedule's interleaving)
    events = []
    for lane in schedule.lanes:
        events.extend(lane)
    events.sort(key=lambda e: (e[3], e[1]))

    for kind, k, m, t0, t1 in events:
        if kind == "F":
            runner.forward(k, m)
        elif kind == "B":
            runner.backward(k, m)
        # "U" tasks would apply the optimizer; the caller does that.
    return runner.finish()


def pipelined_value_and_grad_plan(
        stage_fns: Sequence[Callable],
        loss_fn: Callable,
        stage_params: Sequence[Any],
        microbatches: Sequence[Any],
        fwd_cost: float = 1.0,
        bwd_cost: float = 2.0,
        upd_cost: float = 0.5,
        per_stage_window: bool = True,
) -> Tuple[jnp.ndarray, List[Any]]:
    """Same computation, driven by the shared ExecutionPlan lowering: each
    plan round is one bulk-synchronous pipeline step."""
    runner = _PipeRunner(stage_fns, loss_fn, stage_params, microbatches)
    sched, _meta, plan = lower_pipeline_plan(
        runner.S, runner.M, fwd_cost, bwd_cost, upd_cost,
        per_stage_window=per_stage_window)
    registry = {
        F: BatchSpec(run_one=lambda tid, d: runner.forward(d[1], d[2])),
        B: BatchSpec(run_one=lambda tid, d: runner.backward(d[1], d[2])),
        U: BatchSpec(run_one=lambda tid, d: None),  # caller applies optimizer
    }
    plan.execute(sched, registry)
    return runner.finish()
