"""Pipeline-parallel schedule synthesis from a QuickSched task graph.

Instead of hard-coding 1F1B/GPipe, the pipeline schedule EMERGES from the
paper's machinery:

  * tasks: F(s,m) forward and B(s,m) backward per (stage s, microbatch m),
    plus one weight-update task U(s) per stage;
  * dependencies: F(s,m) ← F(s-1,m);  B(s,m) ← B(s+1,m);
    B(last,m) ← F(last,m);  U(s) ← all B(s,·) (via the wait counter);
  * conflicts: every task on stage s locks the stage resource (a device can
    run one thing at a time); B(s,m) additionally locks the *gradient
    accumulation buffer* resource g_s — the paper's motivating
    "order-independent but serialized" case (§1: FMM force accumulation);
    U(s) locks g_s too, so it conflicts with every accumulation without a
    fixed order.
  * priorities: critical-path weights make deep-stage forwards urgent —
    exactly the property that turns the greedy schedule into 1F1B rather
    than GPipe-style fill-drain.

``synthesize_schedule`` runs the discrete-event engine (one queue per
stage, ownership pinned, no stealing — placement is physical) and returns
per-stage timelines; ``bubble_fraction`` compares against the analytic
1F1B bubble  (S-1)/(M+S-1).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core import ExecutionPlan, QSched, lower, simulate

F, B, U = 0, 1, 2
KIND = {F: "F", B: "B", U: "U"}


def build_pipeline_graph(n_stages: int, n_micro: int, fwd_cost: float = 1.0,
                         bwd_cost: float = 2.0, upd_cost: float = 0.5,
                         max_in_flight: int = 0,
                         per_stage_window: bool = False) -> Tuple[QSched, Dict]:
    """``max_in_flight`` > 0 bounds the activation stash per stage: F(s,m)
    additionally depends on B(s, m - W).  ``per_stage_window`` uses the
    1F1B stash profile W_k = n_stages - k, under which the greedy
    critical-path schedule reproduces the 1F1B bubble AND memory exactly
    (benchmarks/pipeline_bubble.py) — 1F1B *emerges*, it is not coded."""
    s = QSched(nr_queues=n_stages, reown=False)
    stage_res = [s.addres(owner=k) for k in range(n_stages)]
    grad_res = [s.addres(owner=k, parent=stage_res[k])
                for k in range(n_stages)]
    fid: Dict[Tuple[int, int], int] = {}
    bid: Dict[Tuple[int, int], int] = {}
    for m in range(n_micro):
        for k in range(n_stages):
            t = s.addtask(F, data=("F", k, m), cost=fwd_cost)
            s.addlock(t, stage_res[k])
            if k > 0:
                s.addunlock(fid[k - 1, m], t)
            fid[k, m] = t
    for m in range(n_micro):
        for k in reversed(range(n_stages)):
            t = s.addtask(B, data=("B", k, m), cost=bwd_cost)
            s.addlock(t, grad_res[k])     # conflict: grad accumulation
            if k == n_stages - 1:
                s.addunlock(fid[k, m], t)
            else:
                s.addunlock(bid[k + 1, m], t)
            bid[k, m] = t
    if max_in_flight > 0 or per_stage_window:  # activation-memory throttle
        for k in range(n_stages):
            w = (n_stages - k) if per_stage_window else max_in_flight
            for m in range(w, n_micro):
                s.addunlock(bid[k, m - w], fid[k, m])
    for k in range(n_stages):
        t = s.addtask(U, data=("U", k), cost=upd_cost)
        s.addlock(t, grad_res[k])
        for m in range(n_micro):
            s.addunlock(bid[k, m], t)
    return s, {"fid": fid, "bid": bid, "stage_res": stage_res}


@dataclass
class PipelineSchedule:
    n_stages: int
    n_micro: int
    makespan: float
    # per stage: ordered [(kind, stage, micro, t0, t1)]
    lanes: List[List[Tuple[str, int, int, float, float]]]
    work_time: float

    def order_for_stage(self, k: int) -> List[Tuple[str, int]]:
        """[(F|B|U, microbatch)] in execution order — feed to an executor."""
        return [(kind, m) for kind, _, m, _, _ in self.lanes[k]]


def synthesize_schedule(n_stages: int, n_micro: int, fwd_cost: float = 1.0,
                        bwd_cost: float = 2.0, upd_cost: float = 0.5,
                        max_in_flight: int = 0,
                        per_stage_window: bool = False) -> PipelineSchedule:
    sched, meta = build_pipeline_graph(n_stages, n_micro, fwd_cost,
                                       bwd_cost, upd_cost, max_in_flight,
                                       per_stage_window)
    res = simulate(sched, n_stages)
    sched.validate_schedule(res.timeline)
    lanes: List[List] = [[] for _ in range(n_stages)]
    for ev in res.timeline:
        kind, k, *rest = sched.tasks[ev.tid].data
        m = rest[0] if rest else -1
        lanes[k].append((kind, k, m, ev.t0, ev.t1))
    for lane in lanes:
        lane.sort(key=lambda e: e[3])
    work = sum(ev.t1 - ev.t0 for ev in res.timeline)
    return PipelineSchedule(n_stages, n_micro, res.makespan, lanes, work)


def lower_pipeline_plan(n_stages: int, n_micro: int, fwd_cost: float = 1.0,
                        bwd_cost: float = 2.0, upd_cost: float = 0.5,
                        max_in_flight: int = 0,
                        per_stage_window: bool = False
                        ) -> Tuple[QSched, Dict, ExecutionPlan]:
    """Lower the pipeline graph through the shared ExecutionPlan layer: each
    round is one bulk-synchronous pipeline step (per-stage conflicts cap a
    round at one task per stage; grad-buffer conflicts keep accumulation and
    the update exclusive).  The plan cache means a trainer loop rebuilding
    the same (S, M, costs) graph every step skips re-lowering.  The returned
    plan executes on any registered backend (``core.backends``) —
    ``exec.pipelined_value_and_grad_plan`` drives it end to end, including
    the single-dispatch ``engine`` megakernel path."""
    sched, meta = build_pipeline_graph(n_stages, n_micro, fwd_cost, bwd_cost,
                                       upd_cost, max_in_flight,
                                       per_stage_window)
    plan = lower(sched, nr_lanes=n_stages)
    return sched, meta, plan


def bubble_fraction(ps: PipelineSchedule) -> float:
    return 1.0 - ps.work_time / (ps.n_stages * ps.makespan)


def one_f_one_b_bubble(n_stages: int, n_micro: int) -> float:
    """Analytic 1F1B bubble fraction (equal fwd+bwd per microbatch)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
