from .qsched_pipeline import (PipelineSchedule, build_pipeline_graph,
                              bubble_fraction, lower_pipeline_plan,
                              one_f_one_b_bubble, synthesize_schedule)
from .exec import (dense_stage, mse_loss, pipelined_value_and_grad,
                   pipelined_value_and_grad_plan)

__all__ = ["build_pipeline_graph", "synthesize_schedule", "PipelineSchedule",
           "bubble_fraction", "one_f_one_b_bubble", "lower_pipeline_plan",
           "pipelined_value_and_grad", "pipelined_value_and_grad_plan",
           "dense_stage", "mse_loss"]
