"""Fault-tolerant checkpointing.

Properties a 1000-node run needs, all implemented and tested:
  * **atomic**: leaves are written to ``step_<N>.tmp/`` and the directory is
    ``os.rename``d into place only after an fsync'd manifest — a crash
    mid-save never corrupts the latest checkpoint;
  * **restartable**: ``latest_step`` + deterministic data pipeline
    (``SyntheticTokens.batch_at(step)``) give bit-identical continuation
    (tests/test_traincore.py::test_failure_recovery);
  * **resharding restore**: leaves are saved as full (host-gathered) arrays
    with their tree paths; ``restore_checkpoint`` re-places them under ANY
    mesh/sharding (elastic scaling: save on mesh A, restore on mesh B);
  * **async**: ``CheckpointManager(async_save=True)`` snapshots to host
    memory synchronously (cheap) and writes in a background thread, so the
    train loop is blocked only for the device→host copy;
  * **retention**: keeps the newest ``keep`` checkpoints.

Format: one ``.npy`` per leaf (path-encoded filename) + ``manifest.json``.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

Pytree = Any


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "__".join(parts) or "leaf"


def _flatten_with_names(tree: Pytree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_leaf_name(path), leaf) for path, leaf in leaves]


def save_checkpoint(ckpt_dir: str, step: int, tree: Pytree,
                    host_tree: Optional[list] = None) -> str:
    """Write checkpoint atomically.  ``host_tree`` (from a prior snapshot)
    skips the device→host copy (async path)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named = host_tree if host_tree is not None else [
        (n, np.asarray(l)) for n, l in _flatten_with_names(tree)]
    manifest = {"step": step, "leaves": []}
    for name, arr in named:
        fn = f"{name}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Pytree,
                       shardings: Optional[Pytree] = None) -> Pytree:
    """Restore into the structure of ``like``; if ``shardings`` is given the
    leaves are placed with those shardings (RESHARDING: the saved mesh is
    irrelevant — elastic restarts on a different topology just work)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    names = [n for n, _ in _flatten_with_names(like)]
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(names))
    out = []
    for name, leaf, shd in zip(names, leaves_like, shard_leaves):
        entry = by_name.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(os.path.join(path, entry["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out)


class CheckpointManager:
    def __init__(self, ckpt_dir: str, keep: int = 3,
                 async_save: bool = False):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Pytree) -> None:
        self.wait()
        if not self.async_save:
            save_checkpoint(self.dir, step, tree)
            self._gc()
            return
        # synchronous device→host snapshot, asynchronous disk write
        host = [(n, np.asarray(l)) for n, l in _flatten_with_names(tree)]

        def work():
            try:
                save_checkpoint(self.dir, step, None, host_tree=host)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.dir)

    def restore(self, step: int, like: Pytree,
                shardings: Optional[Pytree] = None) -> Pytree:
        return restore_checkpoint(self.dir, step, like, shardings)
