from .optimizers import (OptState, adamw_init, adamw_update, adafactor_init,
                         adafactor_update, clip_by_global_norm,
                         cosine_schedule, default_optimizer_for, global_norm,
                         make_optimizer)

__all__ = ["OptState", "adamw_init", "adamw_update", "adafactor_init",
           "adafactor_update", "clip_by_global_norm", "make_optimizer",
           "cosine_schedule", "default_optimizer_for", "global_norm"]
