"""Optimizers (no external deps): AdamW and Adafactor over arbitrary pytrees.

Adafactor (factored second moment) is selected automatically for the
≥600 B-parameter MoEs: full Adam moments for a 1 T-param model are 8 TB of
fp32 — more than a 512-chip v5e pod's HBM — while factored moments are
~O(rows+cols) (see EXPERIMENTS.md §Dry-run memory table).

All states are elementwise (Adam) or row/col reductions (Adafactor) of the
parameters, so they inherit the parameter PartitionSpecs (ZeRO-style: the
FSDP axis shards them with the weights).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Pytree


# --- utils --------------------------------------------------------------------

def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Pytree, max_norm: float
                        ) -> Tuple[Pytree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


# --- AdamW -----------------------------------------------------------------------

def adamw_init(params: Pytree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), {"m": zeros, "v": jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)})


def adamw_update(grads: Pytree, state: OptState, params: Pytree,
                 lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, wd: float = 0.1) -> Tuple[Pytree, OptState]:
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        u = u + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.inner["m"])
    flat_v = treedef.flatten_up_to(state.inner["v"])
    new = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_m = treedef.unflatten([n[1] for n in new])
    new_v = treedef.unflatten([n[2] for n in new])
    return new_p, OptState(step, {"m": new_m, "v": new_v})


# --- Adafactor -----------------------------------------------------------------------

def adafactor_init(params: Pytree) -> OptState:
    def init_leaf(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(init_leaf, params,
                                 is_leaf=lambda x: isinstance(x, jnp.ndarray)))


def adafactor_update(grads: Pytree, state: OptState, params: Pytree,
                     lr, decay: float = 0.99, eps: float = 1e-30,
                     clip_thresh: float = 1.0, wd: float = 0.0
                     ) -> Tuple[Pytree, OptState]:
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    def upd(g, s, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            v_hat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
            u = g * jax.lax.rsqrt(jnp.maximum(v_hat, eps))
            ns = {"vr": vr, "vc": vc}
        else:
            v = decay * s["v"] + (1 - decay) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
            ns = {"v": v}
        # update clipping (RMS-based)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip_thresh)
        u = u + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), ns

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state.inner)
    new = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_s = treedef.unflatten([n[1] for n in new])
    return new_p, OptState(step, new_s)


# --- factory -----------------------------------------------------------------------

def make_optimizer(name: str, lr, **kw):
    """Returns (init_fn, update_fn(grads, state, params) -> (params, state))."""
    if name == "adamw":
        return adamw_init, functools.partial(adamw_update, lr=lr, **kw)
    if name == "adafactor":
        return adafactor_init, functools.partial(adafactor_update, lr=lr, **kw)
    raise ValueError(name)


def default_optimizer_for(cfg) -> str:
    """Adafactor for the ≥600B MoEs (HBM fit — DESIGN.md §5), AdamW else."""
    return "adafactor" if cfg.param_count() > 3e11 else "adamw"
