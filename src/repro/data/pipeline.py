"""Deterministic synthetic token pipeline.

Generates a learnable Markov-ish token stream (next token is a fixed
permutation of the current one with noise), seeded per (epoch, step, shard)
so that (a) restarts are bit-reproducible from the step counter alone — the
checkpoint/restart test relies on this — and (b) each data-parallel shard
draws a disjoint stream.  Deterministic restart-from-step is the
fault-tolerance property a real distributed loader must provide; a file
loader would track (file, offset) the same way.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticTokens:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, noise: float = 0.1,
                 shard_id: int = 0, num_shards: int = 1):
        assert global_batch % num_shards == 0
        self.vocab = vocab
        self.seq = seq_len
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.noise = noise
        self.shard_id = shard_id
        self.num_shards = num_shards
        rng = np.random.default_rng(seed)           # shared permutation
        self.perm = rng.permutation(vocab)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, shard): restartable."""
        rng = np.random.default_rng(
            (self.seed, step, self.shard_id, 0xBEEF))
        b, s = self.local_batch, self.seq
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, b)
        flips = rng.random((b, s)) < self.noise
        rand = rng.integers(0, self.vocab, (b, s))
        for t in range(1, s):
            nxt = self.perm[toks[:, t - 1]]
            toks[:, t] = np.where(flips[:, t], rand[:, t], nxt)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def batch_specs(cfg, seq_len: int, global_batch: int,
                mode: str = "train") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a step —
    the dry-run's input_specs() building block (no allocation)."""
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if mode in ("train", "prefill"):
        s = seq_len
        if cfg.family == "vlm":
            s = seq_len - cfg.n_vis_tokens
            specs["vis_embeds"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.n_vis_tokens, cfg.d_model),
                jax.numpy.dtype(cfg.dtype))
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.enc_seq, cfg.d_model),
                jax.numpy.dtype(cfg.dtype))
        specs["tokens"] = jax.ShapeDtypeStruct((global_batch, s),
                                               jax.numpy.int32)
    elif mode == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((global_batch, 1),
                                               jax.numpy.int32)
        specs["pos"] = jax.ShapeDtypeStruct((global_batch,), jax.numpy.int32)
    else:
        raise ValueError(mode)
    return specs
