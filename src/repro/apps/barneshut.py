"""Task-based Barnes-Hut tree-code (paper §4.2).

Particles are sorted hierarchically so every cell owns a *contiguous* slice
of the global particle array (paper Fig 10) — cells at every level can hand
their particle block straight to a vectorised kernel.  Cells are
*hierarchical resources* (cell.res.parent = parent cell's res), so a task
locking a cell conflicts with tasks locking any ancestor or descendant —
exactly the write-set semantics of force accumulation.

Task types (paper Fig 16 + §4.2):
  * ``T_SELF``  — all pairwise interactions inside one task-stop cell
                  (single-cell recursion stops when not split or
                  count ≤ n_task);  locks the cell.
  * ``T_PAIR``  — interactions spanning two neighbouring cells (pair
                  recursion stops when not both split or
                  count_i·count_j ≤ n_task²);  locks both cells.
  * ``T_PC``    — particle-cell (centre-of-mass) interactions for one
                  *leaf* cell (the leaf "does its own tree walk");  locks
                  the leaf.
  * ``T_COM``   — centre-of-mass of one cell; children's COM tasks unlock
                  the parent's (bottom-up); every T_PC depends on the root
                  COM.

The interaction partition is built by the standard dual tree walk with
neighbour pruning (comp_self/comp_pair of paper Fig 15, executed at graph
build time):  a non-neighbour pair (a,b) met during the walk contributes
COM interactions (leaves(a) ← com(b), leaves(b) ← com(a)); a pair with at
least one unsplit side contributes a direct block.  This is exact: every
directed particle pair is covered exactly once (tested).

Execution modes (``BHState.run`` / ``solve``; all dispatched through the
core backend registry, ``core/backends.py`` — no mode branching here):
  * ``sequential`` — core SequentialExecutor drains the scheduler in
    priority order (functional jnp accumulation, traceable);
  * ``rounds``     — the shared ExecutionPlan lowering: bulk-synchronous
    conflict-free rounds, the SPMD execution of the BH graph (matches
    ``sequential`` up to float reassociation; tested to 1e-4);
  * ``engine``     — the device-resident engine (DESIGN.md §Engine): tasks
    expand into direct-interaction work items over zero-mass-padded leaf
    blocks, the plan lowers to descriptor tables, and the whole solve runs
    as ONE jitted dispatch of the fused Barnes-Hut megakernel;
  * ``threaded``   — core ThreadedExecutor over a shared numpy buffer,
    where the hierarchical resource locks are the only thing preventing
    lost updates (the paper's conflict-exclusion claim, tested for real).
"""

from __future__ import annotations

import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import (BatchSpec, EngineHooks, QSched, get_backend,
                        run_plan)
from repro.kernels.nbody import ops
from repro.kernels.nbody.ref import DEFAULT_EPS

T_SELF, T_PAIR, T_PC, T_COM = range(4)
TASK_NAMES = {T_SELF: "self", T_PAIR: "pair_pp", T_PC: "pair_pc",
              T_COM: "com"}


@dataclass
class Cell:
    cid: int
    loc: np.ndarray          # lower corner (3,)
    h: float                 # edge length (cubic cells)
    start: int               # first particle index (contiguous block)
    count: int
    depth: int
    parent: int = -1
    split: bool = False
    children: List[int] = field(default_factory=list)
    res: int = -1
    task_com: int = -1


class Octree:
    """Recursive octree with hierarchical particle sort (paper Fig 10)."""

    def __init__(self, x: np.ndarray, m: np.ndarray, n_max: int = 100):
        assert x.shape[1] == 3
        self.n = x.shape[0]
        self.n_max = n_max
        self.x = np.array(x, dtype=np.float64)
        self.m = np.array(m, dtype=np.float64)
        self.cells: List[Cell] = []
        lo = self.x.min(axis=0)
        width = float((self.x.max(axis=0) - lo).max()) * (1 + 1e-9) + 1e-30
        sys.setrecursionlimit(max(sys.getrecursionlimit(), 100000))
        self._build(lo, width, 0, self.n, 0, -1)
        self.x = self.x.T.copy()  # → (3, N) kernel layout after sorting

    def _build(self, loc, h, start, count, depth, parent) -> int:
        cid = len(self.cells)
        cell = Cell(cid, np.array(loc), h, start, count, depth, parent)
        self.cells.append(cell)
        if count > self.n_max:
            cell.split = True
            seg = slice(start, start + count)
            xs = self.x[seg]
            mid = loc + h / 2
            octant = ((xs[:, 0] >= mid[0]).astype(np.int8) * 4
                      + (xs[:, 1] >= mid[1]).astype(np.int8) * 2
                      + (xs[:, 2] >= mid[2]).astype(np.int8))
            order = np.argsort(octant, kind="stable")
            self.x[seg] = xs[order]
            self.m[seg] = self.m[seg][order]
            counts = np.bincount(octant, minlength=8)
            off = start
            for o in range(8):
                c = int(counts[o])
                if c == 0:
                    continue
                cloc = loc + np.array([h / 2 * ((o >> 2) & 1),
                                       h / 2 * ((o >> 1) & 1),
                                       h / 2 * (o & 1)])
                child = self._build(cloc, h / 2, off, c, depth + 1, cid)
                cell.children.append(child)
                off += c
        return cid

    def neighbours(self, a: int, b: int) -> bool:
        ca, cb = self.cells[a], self.cells[b]
        tol = 1e-9 * (ca.h + cb.h)
        for d in range(3):
            if (ca.loc[d] > cb.loc[d] + cb.h + tol
                    or cb.loc[d] > ca.loc[d] + ca.h + tol):
                return False
        return True

    def leaves_of(self, c: int) -> List[int]:
        cell = self.cells[c]
        if not cell.split:
            return [c]
        out: List[int] = []
        stack = [c]
        while stack:
            k = stack.pop()
            ck = self.cells[k]
            if ck.split:
                stack.extend(ck.children)
            else:
                out.append(k)
        return out


@dataclass
class BHGraph:
    sched: QSched
    tree: Octree
    # per-task work lists (indices into tree.cells)
    self_blocks: Dict[int, List[int]]                  # tid -> cells (direct self)
    self_pairs: Dict[int, List[Tuple[int, int]]]       # tid -> (a,b) direct pairs
    pair_pairs: Dict[int, List[Tuple[int, int]]]       # tid -> (a,b) direct pairs
    pc_lists: Dict[int, List[int]]                     # tid -> com source cells
    task_cell: Dict[int, Tuple]                        # tid -> cell payload
    counts: Dict[str, int]


def build_graph(tree: Octree, n_task: int = 5000, nr_queues: int = 1,
                reown: bool = False) -> BHGraph:
    assert n_task >= tree.n_max, "n_task must be >= n_max for stop-cell containment"
    s = QSched(nr_queues=nr_queues, reown=reown)
    # resources: one per cell, hierarchical; ownership by parts-array slice
    for c in tree.cells:
        owner = c.start * nr_queues // max(tree.n, 1)
        parent_res = tree.cells[c.parent].res if c.parent != -1 else -1
        c.res = s.addres(owner=owner, parent=parent_res)

    # --- COM tasks (bottom-up dependencies) -------------------------------
    for c in tree.cells:
        # leaves reduce over their particles; inner cells combine 8 children
        cost = float(c.count) if not c.split else float(len(c.children))
        c.task_com = s.addtask(T_COM, data=("com", c.cid), cost=cost)
        s.adduse(c.task_com, c.res)
    for c in tree.cells:
        if c.parent != -1:
            s.addunlock(c.task_com, tree.cells[c.parent].task_com)
    root_com = tree.cells[0].task_com

    self_blocks: Dict[int, List[int]] = {}
    self_pairs: Dict[int, List[Tuple[int, int]]] = {}
    pair_pairs: Dict[int, List[Tuple[int, int]]] = {}
    com_per_leaf: Dict[int, List[int]] = {}
    task_cell: Dict[int, Tuple] = {}

    def com_add(a: int, b: int) -> None:
        for leaf in tree.leaves_of(a):
            com_per_leaf.setdefault(leaf, []).append(b)

    # --- inner dual walk: collect direct work for one task ----------------
    def walk_self(c: int, tid: int) -> None:
        cell = tree.cells[c]
        if cell.split:
            ch = cell.children
            for a in ch:
                walk_self(a, tid)
            for i in range(len(ch)):
                for j in range(i + 1, len(ch)):
                    walk_pair(ch[i], ch[j], tid, self_pairs)
        else:
            self_blocks.setdefault(tid, []).append(c)

    def walk_pair(a: int, b: int, tid: int, sink) -> None:
        if not tree.neighbours(a, b):
            com_add(a, b)
            com_add(b, a)
            return
        ca, cb = tree.cells[a], tree.cells[b]
        if ca.split and cb.split:
            for i in ca.children:
                for j in cb.children:
                    walk_pair(i, j, tid, sink)
        elif ca.split:
            for i in ca.children:
                walk_pair(i, b, tid, sink)
        elif cb.split:
            for j in cb.children:
                walk_pair(a, j, tid, sink)
        else:
            sink.setdefault(tid, []).append((a, b))

    # --- task creation (paper Fig 16 stop conditions) ---------------------
    def make_tasks(ci: int, cj: Optional[int]) -> None:
        if cj is None:
            cell = tree.cells[ci]
            if cell.split and cell.count > n_task:
                ch = cell.children
                for a in ch:
                    make_tasks(a, None)
                for i in range(len(ch)):
                    for j in range(i + 1, len(ch)):
                        make_tasks(ch[i], ch[j])
            else:
                tid = s.addtask(T_SELF, data=("self", ci),
                                cost=float(cell.count) ** 2)
                s.addlock(tid, cell.res)
                task_cell[tid] = ("self", ci)
                walk_self(ci, tid)
        else:
            if not tree.neighbours(ci, cj):
                com_add(ci, cj)
                com_add(cj, ci)
                return
            a, b = tree.cells[ci], tree.cells[cj]
            if a.split and b.split and a.count * b.count > n_task * n_task:
                for i in a.children:
                    for j in b.children:
                        make_tasks(i, j)
            else:
                tid = s.addtask(T_PAIR, data=("pair", ci, cj),
                                cost=float(a.count) * float(b.count))
                s.addlock(tid, a.res)
                s.addlock(tid, b.res)
                task_cell[tid] = ("pair", ci, cj)
                walk_pair(ci, cj, tid, pair_pairs)

    make_tasks(0, None)

    # --- particle-cell tasks: one per *leaf* (paper: 32 768 for 1M) -------
    pc_lists: Dict[int, List[int]] = {}
    for c in tree.cells:
        if c.split:
            continue
        srcs = com_per_leaf.get(c.cid, [])
        tid = s.addtask(T_PC, data=("pc", c.cid), cost=float(c.count))
        s.addlock(tid, c.res)
        s.addunlock(root_com, tid)  # all COMs ready before any pc walk
        task_cell[tid] = ("pc", c.cid)
        pc_lists[tid] = srcs

    by_type: Dict[int, int] = {}
    for t in s.tasks:
        by_type[t.type] = by_type.get(t.type, 0) + 1
    counts = {
        "tasks": s.nr_tasks,
        "self": by_type.get(T_SELF, 0),
        "pair_pp": by_type.get(T_PAIR, 0),
        "pair_pc": by_type.get(T_PC, 0),
        "com": by_type.get(T_COM, 0),
        "resources": len(s.resources),
        "locks": s.nr_locks,
        "deps": s.nr_deps,
    }
    return BHGraph(s, tree, self_blocks, self_pairs, pair_pairs, pc_lists,
                   task_cell, counts)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

class BHState:
    """Holds (3,N) positions, masses, accumulated accelerations and per-cell
    COM values; executes tasks by id.

    Two accumulation modes:
      * ``jnp``   — functional ``.at[].add`` updates (traceable; used by the
        sequential executor and jit round execution);
      * ``numpy`` — in-place slice adds on a shared buffer (used by the
        threaded executor: the resource locks are the ONLY thing preventing
        concurrent read-modify-write races on overlapping cell ranges —
        this is the paper's conflict-exclusion claim, tested for real).
    """

    def __init__(self, g: BHGraph, backend: str = "ref",
                 eps: float = DEFAULT_EPS, accumulate: str = "jnp"):
        self.g = g
        self.backend = backend
        self.eps = eps
        self.accumulate = accumulate
        self.x = jnp.asarray(g.tree.x, dtype=jnp.float32)       # (3, N)
        self.m = jnp.asarray(g.tree.m, dtype=jnp.float32)       # (N,)
        self._layout = None                  # engine leaf blocks, lazy
        ncells = len(g.tree.cells)
        if accumulate == "numpy":
            self._acc_np = np.zeros((3, g.tree.n), np.float32)
            self._com_np = np.zeros((3, ncells), np.float32)
            self._cmass_np = np.zeros((ncells,), np.float32)
        else:
            self.acc = jnp.zeros_like(self.x)
            self.com: Dict[int, jnp.ndarray] = {}
            self.cmass: Dict[int, jnp.ndarray] = {}

    def result(self) -> jnp.ndarray:
        if self.accumulate == "numpy":
            return jnp.asarray(self._acc_np)
        return self.acc

    def _rng(self, cid: int) -> slice:
        c = self.g.tree.cells[cid]
        return slice(c.start, c.start + c.count)

    # -- accumulation primitives -------------------------------------------
    def _add_acc(self, r: slice, val: jnp.ndarray) -> None:
        if self.accumulate == "numpy":
            self._acc_np[:, r] += np.asarray(val)
        else:
            self.acc = self.acc.at[:, r].add(val)

    def _set_com(self, cid: int, com, mass) -> None:
        if self.accumulate == "numpy":
            self._com_np[:, cid] = np.asarray(com)
            self._cmass_np[cid] = float(mass)
        else:
            self.com[cid] = com
            self.cmass[cid] = mass

    def _get_coms(self, cids: List[int]):
        if self.accumulate == "numpy":
            idx = np.asarray(cids)
            return (jnp.asarray(self._com_np[:, idx]),
                    jnp.asarray(self._cmass_np[idx]))
        return (jnp.stack([self.com[k] for k in cids], axis=1),
                jnp.stack([self.cmass[k] for k in cids]))

    # -- task bodies ---------------------------------------------------------
    def exec_task(self, ttype: int, data, tid: int = -1) -> None:
        g, be, eps = self.g, self.backend, self.eps
        if ttype == T_COM:
            cid = data[1]
            c = g.tree.cells[cid]
            if c.split:
                xs, ms = self._get_coms(c.children)
                tot = jnp.sum(ms)
                self._set_com(cid, (xs @ ms) / jnp.maximum(tot, 1e-30), tot)
            else:
                r = self._rng(cid)
                tot = jnp.sum(self.m[r])
                self._set_com(cid, (self.x[:, r] @ self.m[r])
                              / jnp.maximum(tot, 1e-30), tot)
            return
        if ttype == T_SELF:
            for c in g.self_blocks.get(tid, []):
                r = self._rng(c)
                self._add_acc(r, ops.acc_self(self.x[:, r], self.m[r], eps, be))
            for a, b in g.self_pairs.get(tid, []):
                self._direct_pair(a, b)
        elif ttype == T_PAIR:
            for a, b in g.pair_pairs.get(tid, []):
                self._direct_pair(a, b)
        elif ttype == T_PC:
            srcs = g.pc_lists.get(tid, [])
            if not srcs:
                return
            r = self._rng(data[1])
            xj, mj = self._get_coms(srcs)
            self._add_acc(r, ops.acc_pair(self.x[:, r], xj, mj, eps, be))
        else:
            raise ValueError(f"unknown task type {ttype}")

    def _direct_pair(self, a: int, b: int) -> None:
        ra, rb = self._rng(a), self._rng(b)
        be, eps = self.backend, self.eps
        self._add_acc(ra, ops.acc_pair(self.x[:, ra], self.x[:, rb],
                                       self.m[rb], eps, be))
        self._add_acc(rb, ops.acc_pair(self.x[:, rb], self.x[:, ra],
                                       self.m[ra], eps, be))

    # -- engine lowering -------------------------------------------------------
    def _engine_layout(self):
        """Leaf-block layout for the device engine: leaf cells in cid order,
        each owning a zero-mass-padded (3, P) particle block (P = max leaf
        count — ragged cells become dense slabs the megakernel can address
        uniformly).  Computed once per state."""
        if self._layout is not None:
            return self._layout
        tree = self.g.tree
        leaves = [c.cid for c in tree.cells if not c.split]
        slot = {cid: k for k, cid in enumerate(leaves)}
        P = max(tree.cells[cid].count for cid in leaves)
        xs = np.zeros((len(leaves), 3, P), np.float32)
        ms = np.zeros((len(leaves), P), np.float32)
        x_np, m_np = np.asarray(self.x), np.asarray(self.m)
        for k, cid in enumerate(leaves):
            c = tree.cells[cid]
            xs[k, :, :c.count] = x_np[:, c.start:c.start + c.count]
            ms[k, :c.count] = m_np[c.start:c.start + c.count]
        self._layout = (leaves, slot, P, xs, ms)
        return self._layout

    def batch_registry(self) -> Dict[int, BatchSpec]:
        """BatchSpecs for the ExecutionPlan ``rounds`` mode.  Cell blocks
        are ragged (per-cell particle counts differ), so every type runs
        per-task; the plan still provides the bulk-synchronous round
        structure (each round is one SPMD step, conflict-freedom proven at
        lowering time) and the lane assignment.

        Each spec also carries its engine ``encode``: a task expands into
        its direct-interaction work items over the padded leaf layout —
        self blocks, one row per pair *direction* (so every row has exactly
        one write target), COM reductions (leaf or ≤8-children inner), and
        particle-cell rows whose ragged COM-source lists chunk into
        ≤8-cell rows padded with the zero-mass dummy cell (the encoders
        are pure — no side tables).  The encoders resolve the leaf layout
        lazily, so the host-only ``rounds`` mode never builds the padded
        blocks.  DESIGN.md §Engine."""
        def one(ttype):
            return lambda tid, data: self.exec_task(ttype, data, tid)

        g = self.g
        cells = g.tree.cells
        ncells = len(cells)          # dummy pad cell id == ncells
        kmax = engine.BH_MAX_CHILDREN

        def slot_of(cid):
            return self._engine_layout()[1][cid]

        def pad_cells(ids):
            return list(ids) + [ncells] * (kmax - len(ids))

        def enc_com(tid, data):
            c = cells[data[1]]
            if c.split:
                return [(engine.BH_COM_INNER, c.cid, *pad_cells(c.children))]
            return [(engine.BH_COM_LEAF, c.cid, slot_of(c.cid))]

        def enc_pairs(pairs):
            rows = []
            for a, b in pairs:
                rows.append((engine.BH_PP, slot_of(a), slot_of(b)))
                rows.append((engine.BH_PP, slot_of(b), slot_of(a)))
            return rows

        def enc_self(tid, data):
            rows = [(engine.BH_SELF, slot_of(c))
                    for c in g.self_blocks.get(tid, [])]
            return rows + enc_pairs(g.self_pairs.get(tid, []))

        def enc_pair(tid, data):
            return enc_pairs(g.pair_pairs.get(tid, []))

        def enc_pc(tid, data):
            srcs = g.pc_lists.get(tid, [])
            la = slot_of(data[1]) if srcs else -1
            return [(engine.BH_PC, la, *pad_cells(srcs[i:i + kmax]))
                    for i in range(0, len(srcs), kmax)]

        enc = {T_SELF: enc_self, T_PAIR: enc_pair, T_PC: enc_pc,
               T_COM: enc_com}
        return {t: BatchSpec(run_one=one(t), encode=enc[t])
                for t in (T_SELF, T_PAIR, T_PC, T_COM)}

    def engine_hooks(self) -> EngineHooks:
        """Engine-family hooks for the backend registry (DESIGN.md
        §Engine): the fused BH megakernel over zero-mass-padded leaf
        blocks; writeback scatters the padded leaf accelerations back.
        The leaf layout resolves lazily, so building the hooks for a
        host-only run costs nothing."""
        def statics():
            _, _, _, xs, ms = self._engine_layout()
            return jnp.asarray(xs), jnp.asarray(ms)

        def buffers():
            leaves, _, P, _, _ = self._engine_layout()
            ncells = len(self.g.tree.cells)
            return (jnp.zeros((len(leaves), 3, P), jnp.float32),
                    jnp.zeros((ncells + 1, 3), jnp.float32),
                    jnp.zeros((ncells + 1, 1), jnp.float32))

        def writeback(out):
            acc, com, cmass = out
            leaves = self._engine_layout()[0]
            tree = self.g.tree
            ncells = len(tree.cells)
            acc_np = np.zeros((3, tree.n), np.float32)
            acc_host = np.asarray(acc)
            for k, cid in enumerate(leaves):
                c = tree.cells[cid]
                acc_np[:, c.start:c.start + c.count] = \
                    acc_host[k, :, :c.count]
            self.acc = jnp.asarray(acc_np)
            # host numpy rows (one transfer), not ncells tiny device arrays
            com_host, cm_host = np.asarray(com), np.asarray(cmass)
            for cid in range(ncells):
                self.com[cid] = com_host[cid]
                self.cmass[cid] = float(cm_host[cid, 0])

        return EngineHooks(
            arg_width=engine.BH_ARG_WIDTH,
            round_fn=engine.bh_round_fn(float(self.eps)), statics=statics,
            buffers=buffers, writeback=writeback,
            row_access=engine.bh_row_access)

    # -- drivers ---------------------------------------------------------------
    def run(self, mode: str = "sequential", nr_workers: int = 1) -> None:
        """Execute on any registered backend.  Accumulation-mode
        preconditions key off backend *capabilities*, not mode names:
        concurrent backends mutate a shared numpy buffer under the real
        resource locks (the paper's conflict-exclusion claim), while the
        device-resident engine bypasses host accumulation entirely."""
        be = get_backend(mode)
        if be.concurrent:
            # NOTE: no global lock — the resource locks acquired by gettask
            # are what serialises overlapping writes.
            assert self.accumulate == "numpy", (
                "concurrent backends require accumulate='numpy'")
        if be.device_resident:
            assert self.accumulate == "jnp", (
                "the engine bypasses host accumulation; use accumulate='jnp'")
        run_plan(self.g.sched, self.batch_registry(), mode,
                 nr_workers=max(nr_workers, 1),
                 engine=self.engine_hooks())



def solve(x: np.ndarray, m: np.ndarray, n_max: int = 100,
          n_task: int = 5000, backend: str = "ref", mode: str = "sequential",
          nr_workers: int = 1, eps: float = DEFAULT_EPS):
    """End-to-end Barnes-Hut: build tree + graph, execute, return
    (acc (3,N) in sorted order, state, graph)."""
    tree = Octree(x, m, n_max=n_max)
    g = build_graph(tree, n_task=n_task,
                    nr_queues=max(nr_workers, 1))
    st = BHState(g, backend=backend, eps=eps)
    st.run(mode=mode, nr_workers=nr_workers)
    return st.acc, st, g
