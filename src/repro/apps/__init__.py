"""The paper's two validation applications (§4): tiled QR and Barnes-Hut."""
