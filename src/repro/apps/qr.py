"""Task-based tiled QR decomposition (paper §4.1, Buttari et al. 2009).

Four task types on an ``mt × nt`` grid of (b,b) tiles, ``min(mt,nt)``
levels.  Dependency structure follows the paper's §4.1 table (the fully
deterministic variant — see EXPERIMENTS.md for the dependency-count
analysis vs the paper's reported numbers):

  | task    | where        | depends on                          | locks        | uses          |
  | DGEQRF  | i=j=k        | (i,j,k-1)                           | (k,k)        |               |
  | DLARFT  | i=k, j>k     | (i,j,k-1), (k,k,k)                  |              | (k,k), (k,j)  |
  | DTSQRF  | i>k, j=k     | (i,j,k-1), (i-1,j,k)                | (i,k), (k,k) |               |
  | DSSRFT  | i>k, j>k     | (i,j,k-1), (i-1,j,k), (i,k,k)       | (i,j), (k,j) | (i,k)         |

Tiles are resources (for affinity; the paper: "we still model each tile as
a separate resource such that the scheduler can preferentially assign tasks
using the same tiles to the same thread"), initially assigned to queues in
column-major order.

``make_qr_graph`` emits the whole level-k slab of tasks/deps/locks/uses as
numpy index arrays through the scheduler's bulk API (``addtasks`` /
``addunlocks`` / …) — the per-call reference builder it replaced is kept as
``make_qr_graph_loop`` and the two are asserted stream-identical in
``tests/test_plan.py``.

Execution modes (all dispatched through the core backend registry,
``core/backends.py`` — this module contains no mode branching):
  * ``sequential`` — SequentialExecutor drains the scheduler in priority
    order while tracing the tile kernels; wrap in ``jax.jit`` for a single
    XLA program ordered by the QuickSched schedule.
  * ``rounds``     — the shared ExecutionPlan lowering: conflict-free
    rounds whose same-type task groups are *batched with vmap* over stacked
    tiles via the BatchSpec registry.  On TPU each round is one SPMD step
    and the vmap becomes the kernel grid.
  * ``engine``     — the device-resident engine (DESIGN.md §Engine): the
    plan is lowered to descriptor task tables and the whole factorization
    executes as ONE jitted dispatch of fused type-branching Pallas rounds
    over a (ntiles, b, b) tile stack (``backend`` is ignored — the
    megakernel *is* the Pallas path, interpreted on CPU).
  * ``threaded``   — the paper's pthread pool over numpy tiles (host).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import BatchSpec, EngineHooks, QSched, lower, run_plan
from repro.kernels.qr_tile import ops

T_GEQRF, T_LARFT, T_TSQRF, T_SSRFT = range(4)
TASK_NAMES = {T_GEQRF: "DGEQRF", T_LARFT: "DLARFT",
              T_TSQRF: "DTSQRF", T_SSRFT: "DSSRFT"}
# relative costs from the paper's Fig 14 addtask calls
COSTS = {T_GEQRF: 2.0, T_LARFT: 3.0, T_TSQRF: 3.0, T_SSRFT: 5.0}


def _add_resources(s: QSched, mt: int, nt: int,
                   nr_queues: int) -> Dict[Tuple[int, int], int]:
    ntiles = mt * nt
    rid: Dict[Tuple[int, int], int] = {}
    for j in range(nt):          # column-major initial queue assignment
        for i in range(mt):
            owner = (j * mt + i) * nr_queues // ntiles
            rid[i, j] = s.addres(owner=owner)
    return rid


def make_qr_graph(mt: int, nt: int, nr_queues: int = 1,
                  reown: bool = True) -> Tuple[QSched, Dict[Tuple[int, int], int]]:
    """Build the QuickSched graph for an mt×nt tile grid, one vectorized
    level-k slab at a time (identical id/edge streams to the per-call
    reference ``make_qr_graph_loop``)."""
    s = QSched(nr_queues=nr_queues, reown=reown)
    rid = _add_resources(s, mt, nt, nr_queues)
    # tile (i,j) -> resource id, column-major creation order
    last = np.full((mt, nt), -1, dtype=np.int64)   # tid grid, prev level

    def res(i, j):               # rid[i, j] as index arithmetic
        return j * mt + i

    for k in range(min(mt, nt)):
        nk = nt - k - 1          # DLARFT count (j = k+1..nt-1)
        mk = mt - k - 1          # DTSQRF count (i = k+1..mt-1)
        base = s.nr_tasks
        js = np.arange(k + 1, nt, dtype=np.int64)
        is_ = np.arange(k + 1, mt, dtype=np.int64)
        g_tid = base
        larft = base + 1 + np.arange(nk, dtype=np.int64)
        blk = base + 1 + nk + np.arange(mk, dtype=np.int64)[:, None] * (1 + nk)
        tsqrf = blk[:, 0]                                    # (mk,)
        ssrft = blk + 1 + np.arange(nk, dtype=np.int64)[None, :]   # (mk, nk)

        # tasks, creation order: GEQRF, LARFTs, then per i: TSQRF + SSRFTs
        types = ([T_GEQRF] + [T_LARFT] * nk
                 + ([T_TSQRF] + [T_SSRFT] * nk) * mk)
        costv = ([COSTS[T_GEQRF]] + [COSTS[T_LARFT]] * nk
                 + ([COSTS[T_TSQRF]] + [COSTS[T_SSRFT]] * nk) * mk)
        js_l = js.tolist()
        datas = ([(k, k, k)] + [(k, j, k) for j in js_l]
                 + [d for i in range(k + 1, mt)
                    for d in [(i, k, k)] + [(i, j, k) for j in js_l]])
        s.addtasks(types, costv, datas)

        # dependencies, creation order
        dep_src, dep_dst = [], []
        if k > 0:
            dep_src.append(np.asarray([last[k, k]]))
            dep_dst.append(np.asarray([g_tid]))
        if nk:
            if k > 0:            # per j: (GEQRF, larft_j), (last[k,j], larft_j)
                dep_src.append(np.stack(
                    [np.full(nk, g_tid, np.int64), last[k, k + 1:]],
                    axis=1).ravel())
                dep_dst.append(np.repeat(larft, 2))
            else:
                dep_src.append(np.full(nk, g_tid, np.int64))
                dep_dst.append(larft)
        if mk:
            prev_col0 = np.concatenate(([g_tid], tsqrf[:-1]))  # cur[i-1, k]
            prev_row = (np.vstack([larft[None, :], ssrft[:-1]])
                        if nk else np.empty((mk, 0), np.int64))  # cur[i-1, j]
            if k > 0:
                # per i: [(cur[i-1,k], t), (last[i,k], t)]
                #        + per j [(tsqrf_i, s), (cur[i-1,j], s), (last[i,j], s)]
                a_src = np.stack([prev_col0, last[k + 1:, k]], axis=1)
                b_src = np.stack([np.broadcast_to(tsqrf[:, None], (mk, nk)),
                                  prev_row, last[k + 1:, k + 1:]], axis=2)
                dep_src.append(np.concatenate(
                    [a_src, b_src.reshape(mk, -1)], axis=1).ravel())
                a_dst = np.stack([tsqrf, tsqrf], axis=1)
                dep_dst.append(np.concatenate(
                    [a_dst, np.repeat(ssrft, 3, axis=1)], axis=1).ravel())
            else:
                a_src = prev_col0[:, None]
                b_src = np.stack([np.broadcast_to(tsqrf[:, None], (mk, nk)),
                                  prev_row], axis=2)
                dep_src.append(np.concatenate(
                    [a_src, b_src.reshape(mk, -1)], axis=1).ravel())
                dep_dst.append(np.concatenate(
                    [tsqrf[:, None], np.repeat(ssrft, 2, axis=1)],
                    axis=1).ravel())
        if dep_src:
            s.addunlocks(np.concatenate(dep_src), np.concatenate(dep_dst))

        # locks: (GEQRF, (k,k)); per i: (t, (i,k)), (t, (k,k));
        #        per j: (s, (i,j)), (s, (k,j))
        lock_t = [np.asarray([g_tid])]
        lock_r = [np.asarray([res(k, k)])]
        if mk:
            a_t = np.stack([tsqrf, tsqrf], axis=1)
            a_r = np.stack([res(is_, k), np.full(mk, res(k, k), np.int64)],
                           axis=1)
            b_t = np.repeat(ssrft, 2, axis=1)
            b_r = np.stack([res(is_[:, None], js[None, :]),
                            np.broadcast_to(res(k, js)[None, :], (mk, nk))],
                           axis=2).reshape(mk, -1)
            lock_t.append(np.concatenate([a_t, b_t], axis=1).ravel())
            lock_r.append(np.concatenate([a_r, b_r], axis=1).ravel())
        s.addlocks(np.concatenate(lock_t), np.concatenate(lock_r))

        # uses: per j: (larft_j, (k,k)), (larft_j, (k,j));
        #       per i,j: (ssrft_ij, (i,k))
        if nk:
            use_t = [np.repeat(larft, 2)]
            use_r = [np.stack([np.full(nk, res(k, k), np.int64), res(k, js)],
                              axis=1).ravel()]
            if mk:
                use_t.append(ssrft.ravel())
                use_r.append(np.repeat(res(is_, k), nk))
            s.adduses(np.concatenate(use_t), np.concatenate(use_r))

        # fold this level's tids into the grid for level k+1
        last[k, k] = g_tid
        if nk:
            last[k, k + 1:] = larft
        if mk:
            last[k + 1:, k] = tsqrf
            if nk:
                last[k + 1:, k + 1:] = ssrft
    return s, rid


def make_qr_graph_loop(mt: int, nt: int, nr_queues: int = 1,
                       reown: bool = True) -> Tuple[QSched, Dict[Tuple[int, int], int]]:
    """Reference per-call builder (paper Fig 14 shape) — kept as the oracle
    for the vectorized ``make_qr_graph`` (asserted stream-identical in
    tests) and as readable documentation of the dependency table."""
    s = QSched(nr_queues=nr_queues, reown=reown)
    rid = _add_resources(s, mt, nt, nr_queues)
    tid: Dict[Tuple[int, int], int] = {}
    for k in range(min(mt, nt)):
        t = s.addtask(T_GEQRF, data=(k, k, k), cost=COSTS[T_GEQRF])
        s.addlock(t, rid[k, k])
        if (k, k) in tid:
            s.addunlock(tid[k, k], t)
        tid[k, k] = t
        for j in range(k + 1, nt):
            t = s.addtask(T_LARFT, data=(k, j, k), cost=COSTS[T_LARFT])
            s.adduse(t, rid[k, k])
            s.adduse(t, rid[k, j])
            s.addunlock(tid[k, k], t)
            if (k, j) in tid:
                s.addunlock(tid[k, j], t)
            tid[k, j] = t
        for i in range(k + 1, mt):
            t = s.addtask(T_TSQRF, data=(i, k, k), cost=COSTS[T_TSQRF])
            s.addlock(t, rid[i, k])
            s.addlock(t, rid[k, k])
            s.addunlock(tid[i - 1, k], t)   # chain: serializes R_kk updates
            if (i, k) in tid:
                s.addunlock(tid[i, k], t)
            tid[i, k] = t
            for j in range(k + 1, nt):
                t = s.addtask(T_SSRFT, data=(i, j, k), cost=COSTS[T_SSRFT])
                s.addlock(t, rid[i, j])
                s.addlock(t, rid[k, j])
                s.adduse(t, rid[i, k])
                s.addunlock(tid[i, k], t)       # the DTSQRF whose V2 we apply
                s.addunlock(tid[i - 1, j], t)   # chain: row-k tile update order
                if (i, j) in tid:
                    s.addunlock(tid[i, j], t)
                tid[i, j] = t
    return s, rid


# ----------------------------------------------------------------------------
# numerical execution over tiles
# ----------------------------------------------------------------------------

def _split_tiles(a: jnp.ndarray, b: int):
    m, n = a.shape
    mt, nt = m // b, n // b
    return {(i, j): a[i * b:(i + 1) * b, j * b:(j + 1) * b]
            for i in range(mt) for j in range(nt)}, mt, nt


def _assemble_r(tiles, mt, nt, b, dtype):
    rows = []
    for i in range(mt):
        cols = []
        for j in range(nt):
            if i < j:
                cols.append(tiles[i, j])
            elif i == j:
                cols.append(jnp.triu(tiles[i, j]))
            else:
                cols.append(jnp.zeros((b, b), dtype))
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


class _TileState:
    def __init__(self, tiles, backend):
        self.tiles = tiles
        self.t_diag = {}
        self.t_ts = {}
        self.backend = backend
        self.mt = 1 + max(i for i, _ in tiles)
        self.nt = 1 + max(j for _, j in tiles)

    def exec_task(self, ttype, data):
        i, j, k = data
        tl, be = self.tiles, self.backend
        if ttype == T_GEQRF:
            rv, tau, t = ops.geqrf(tl[k, k], backend=be)
            tl[k, k] = rv
            self.t_diag[k] = t
        elif ttype == T_LARFT:
            tl[k, j] = ops.apply_qt(tl[k, k], self.t_diag[k], tl[k, j],
                                    backend=be)
        elif ttype == T_TSQRF:
            r, v2, tau, t = ops.tsqrf(jnp.triu(tl[k, k]), tl[i, k], backend=be)
            tl[k, k] = jnp.triu(r) + jnp.tril(tl[k, k], -1)  # keep V below
            tl[i, k] = v2
            self.t_ts[i, k] = t
        elif ttype == T_SSRFT:
            c1, c2 = ops.apply_tsqt(tl[i, k], self.t_ts[i, k],
                                    tl[k, j], tl[i, j], backend=be)
            tl[k, j] = c1
            tl[i, j] = c2
        else:
            raise ValueError(f"unknown task type {ttype}")

    def batch_registry(self):
        """BatchSpecs for the ExecutionPlan: LARFT/SSRFT groups stack their
        tiles and run one vmapped kernel; GEQRF is singular per round and
        TSQRF batches would mix conflicting same-column updates, so both
        stay per-task.  Each spec also carries its engine ``encode`` — the
        descriptor-row lowering the ``engine`` mode ships to the fused
        megakernel (task types map to themselves; args are column-major
        tile indices, DESIGN.md §Engine)."""
        tl, be = self.tiles, self.backend

        def larft_batch(tids, datas):
            kk = jnp.stack([tl[k, k] for (k, j, _) in datas])
            tt = jnp.stack([self.t_diag[k] for (k, j, _) in datas])
            cc = jnp.stack([tl[k, j] for (k, j, _) in datas])
            out = jax.vmap(
                lambda a, b, c: ops.apply_qt(a, b, c, backend=be))(kk, tt, cc)
            for (k, j, _), o in zip(datas, out):
                tl[k, j] = o

        def ssrft_batch(tids, datas):
            v2 = jnp.stack([tl[i, k] for (i, j, k) in datas])
            tt = jnp.stack([self.t_ts[i, k] for (i, j, k) in datas])
            c1 = jnp.stack([tl[k, j] for (i, j, k) in datas])
            c2 = jnp.stack([tl[i, j] for (i, j, k) in datas])
            o1, o2 = jax.vmap(lambda a, b, c, d: ops.apply_tsqt(
                a, b, c, d, backend=be))(v2, tt, c1, c2)
            for (i, j, k), x1, x2 in zip(datas, o1, o2):
                tl[k, j] = x1
                tl[i, j] = x2

        def one(ttype):
            return lambda tid, d: self.exec_task(ttype, d)

        mt = self.mt

        def res(i, j):
            return j * mt + i

        def enc_geqrf(tid, d):
            i, j, k = d
            return [(engine.QR_GEQRF, res(k, k))]

        def enc_larft(tid, d):
            i, j, k = d
            return [(engine.QR_LARFT, res(k, k), res(k, j))]

        def enc_tsqrf(tid, d):
            i, j, k = d
            return [(engine.QR_TSQRF, res(k, k), res(i, k))]

        def enc_ssrft(tid, d):
            i, j, k = d
            return [(engine.QR_SSRFT, res(i, k), res(k, j), res(i, j))]

        return {
            T_GEQRF: BatchSpec(run_one=one(T_GEQRF), encode=enc_geqrf),
            T_LARFT: BatchSpec(run_one=one(T_LARFT), run_batch=larft_batch,
                               encode=enc_larft),
            T_TSQRF: BatchSpec(run_one=one(T_TSQRF), encode=enc_tsqrf),
            T_SSRFT: BatchSpec(run_one=one(T_SSRFT), run_batch=ssrft_batch,
                               encode=enc_ssrft),
        }

    def engine_hooks(self) -> EngineHooks:
        """Engine-family hooks for the backend registry: stack the tile
        dict into a (ntiles, b, b) buffer (column-major tile index,
        matching the resource ids), run the fused QR megakernel, scatter
        the tiles back."""
        mt, nt = self.mt, self.nt

        def buffers():
            tiles = jnp.stack([self.tiles[i, j]
                               for j in range(nt) for i in range(mt)])
            return tiles, jnp.zeros_like(tiles)

        def writeback(out):
            tiles, _ = out
            for j in range(nt):
                for i in range(mt):
                    self.tiles[i, j] = tiles[j * mt + i]

        return EngineHooks(
            arg_width=engine.QR_ARG_WIDTH,
            round_fn=engine.qr_round_fn(), statics=tuple,
            buffers=buffers, writeback=writeback,
            row_access=engine.qr_row_access)


def run_qr(a: jnp.ndarray, tile: int = 32, mode: str = "sequential",
           backend: str = "pallas", nr_queues: int = 1):
    """Compute the R factor of ``a`` with the QuickSched task graph on any
    registered execution backend.  Returns (R, sched)."""
    tiles, mt, nt = _split_tiles(a, tile)
    sched, _ = make_qr_graph(mt, nt, nr_queues=nr_queues)
    state = _TileState(tiles, backend)
    run_plan(sched, state.batch_registry(), mode,
             nr_workers=max(nr_queues, 1), engine=state.engine_hooks())
    r = _assemble_r(state.tiles, mt, nt, tile, a.dtype)
    return r, sched


def dispatch_counts(a: jnp.ndarray, tile: int = 32, nr_queues: int = 1):
    """(host dispatches of the per-round path, engine dispatches) for
    ``a``'s QR plan — the figure of merit of the device engine
    (``benchmarks/engine_dispatch.py``, DESIGN.md §Engine)."""
    tiles, mt, nt = _split_tiles(jnp.asarray(a), tile)
    sched, _ = make_qr_graph(mt, nt, nr_queues=nr_queues)
    plan = lower(sched, nr_lanes=max(nr_queues, 1))
    host = engine.count_host_dispatches(
        plan, sched, _TileState(tiles, "pallas").batch_registry())
    return host, engine.ENGINE_DISPATCHES_PER_PLAN


def paper_counts(mt: int = 32, nt: int = 32):
    """Structural counts for the paper's 2048² / 64² benchmark matrix."""
    s, _ = make_qr_graph(mt, nt)
    return {
        "tasks": s.nr_tasks,
        "deps": s.nr_deps,
        "resources": len(s.resources),
        "locks": s.nr_locks,
        "uses": s.nr_uses,
    }
