"""Task-based tiled QR decomposition (paper §4.1, Buttari et al. 2009).

Four task types on an ``mt × nt`` grid of (b,b) tiles, ``min(mt,nt)``
levels.  Dependency structure follows the paper's §4.1 table (the fully
deterministic variant — see EXPERIMENTS.md for the dependency-count
analysis vs the paper's reported numbers):

  | task    | where        | depends on                          | locks        | uses          |
  | DGEQRF  | i=j=k        | (i,j,k-1)                           | (k,k)        |               |
  | DLARFT  | i=k, j>k     | (i,j,k-1), (k,k,k)                  |              | (k,k), (k,j)  |
  | DTSQRF  | i>k, j=k     | (i,j,k-1), (i-1,j,k)                | (i,k), (k,k) |               |
  | DSSRFT  | i>k, j>k     | (i,j,k-1), (i-1,j,k), (i,k,k)       | (i,j), (k,j) | (i,k)         |

Tiles are resources (for affinity; the paper: "we still model each tile as
a separate resource such that the scheduler can preferentially assign tasks
using the same tiles to the same thread"), initially assigned to queues in
column-major order.

Execution modes:
  * ``sequential`` — SequentialExecutor drains the scheduler in priority
    order while tracing the tile kernels; wrap in ``jax.jit`` for a single
    XLA program ordered by the QuickSched schedule.
  * ``rounds``     — conflict-aware rounds (static_sched); within a round,
    same-type tasks are *batched with vmap* over stacked tiles: on TPU each
    round is one SPMD step and the vmap becomes the kernel grid.  This is
    the TPU-native execution of the QuickSched schedule.
  * ``threaded``   — the paper's pthread pool over numpy tiles (host).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QSched, SequentialExecutor, conflict_rounds
from repro.kernels.qr_tile import ops

T_GEQRF, T_LARFT, T_TSQRF, T_SSRFT = range(4)
TASK_NAMES = {T_GEQRF: "DGEQRF", T_LARFT: "DLARFT",
              T_TSQRF: "DTSQRF", T_SSRFT: "DSSRFT"}
# relative costs from the paper's Fig 14 addtask calls
COSTS = {T_GEQRF: 2.0, T_LARFT: 3.0, T_TSQRF: 3.0, T_SSRFT: 5.0}


def make_qr_graph(mt: int, nt: int, nr_queues: int = 1,
                  reown: bool = True) -> Tuple[QSched, Dict[Tuple[int, int], int]]:
    """Build the QuickSched graph for an mt×nt tile grid."""
    s = QSched(nr_queues=nr_queues, reown=reown)
    ntiles = mt * nt
    rid: Dict[Tuple[int, int], int] = {}
    for j in range(nt):          # column-major initial queue assignment
        for i in range(mt):
            owner = (j * mt + i) * nr_queues // ntiles
            rid[i, j] = s.addres(owner=owner)
    tid: Dict[Tuple[int, int], int] = {}
    for k in range(min(mt, nt)):
        t = s.addtask(T_GEQRF, data=(k, k, k), cost=COSTS[T_GEQRF])
        s.addlock(t, rid[k, k])
        if (k, k) in tid:
            s.addunlock(tid[k, k], t)
        tid[k, k] = t
        for j in range(k + 1, nt):
            t = s.addtask(T_LARFT, data=(k, j, k), cost=COSTS[T_LARFT])
            s.adduse(t, rid[k, k])
            s.adduse(t, rid[k, j])
            s.addunlock(tid[k, k], t)
            if (k, j) in tid:
                s.addunlock(tid[k, j], t)
            tid[k, j] = t
        for i in range(k + 1, mt):
            t = s.addtask(T_TSQRF, data=(i, k, k), cost=COSTS[T_TSQRF])
            s.addlock(t, rid[i, k])
            s.addlock(t, rid[k, k])
            s.addunlock(tid[i - 1, k], t)   # chain: serializes R_kk updates
            if (i, k) in tid:
                s.addunlock(tid[i, k], t)
            tid[i, k] = t
            for j in range(k + 1, nt):
                t = s.addtask(T_SSRFT, data=(i, j, k), cost=COSTS[T_SSRFT])
                s.addlock(t, rid[i, j])
                s.addlock(t, rid[k, j])
                s.adduse(t, rid[i, k])
                s.addunlock(tid[i, k], t)       # the DTSQRF whose V2 we apply
                s.addunlock(tid[i - 1, j], t)   # chain: row-k tile update order
                if (i, j) in tid:
                    s.addunlock(tid[i, j], t)
                tid[i, j] = t
    return s, rid


# ----------------------------------------------------------------------------
# numerical execution over tiles
# ----------------------------------------------------------------------------

def _split_tiles(a: jnp.ndarray, b: int):
    m, n = a.shape
    mt, nt = m // b, n // b
    return {(i, j): a[i * b:(i + 1) * b, j * b:(j + 1) * b]
            for i in range(mt) for j in range(nt)}, mt, nt


def _assemble_r(tiles, mt, nt, b, dtype):
    rows = []
    for i in range(mt):
        cols = []
        for j in range(nt):
            if i < j:
                cols.append(tiles[i, j])
            elif i == j:
                cols.append(jnp.triu(tiles[i, j]))
            else:
                cols.append(jnp.zeros((b, b), dtype))
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


class _TileState:
    def __init__(self, tiles, backend):
        self.tiles = tiles
        self.t_diag = {}
        self.t_ts = {}
        self.backend = backend

    def exec_task(self, ttype, data):
        i, j, k = data
        tl, be = self.tiles, self.backend
        if ttype == T_GEQRF:
            rv, tau, t = ops.geqrf(tl[k, k], backend=be)
            tl[k, k] = rv
            self.t_diag[k] = t
        elif ttype == T_LARFT:
            tl[k, j] = ops.apply_qt(tl[k, k], self.t_diag[k], tl[k, j],
                                    backend=be)
        elif ttype == T_TSQRF:
            r, v2, tau, t = ops.tsqrf(jnp.triu(tl[k, k]), tl[i, k], backend=be)
            tl[k, k] = jnp.triu(r) + jnp.tril(tl[k, k], -1)  # keep V below
            tl[i, k] = v2
            self.t_ts[i, k] = t
        elif ttype == T_SSRFT:
            c1, c2 = ops.apply_tsqt(tl[i, k], self.t_ts[i, k],
                                    tl[k, j], tl[i, j], backend=be)
            tl[k, j] = c1
            tl[i, j] = c2
        else:
            raise ValueError(f"unknown task type {ttype}")


def run_qr(a: jnp.ndarray, tile: int = 32, mode: str = "sequential",
           backend: str = "pallas", nr_queues: int = 1):
    """Compute the R factor of ``a`` with the QuickSched task graph.
    Returns (R, sched)."""
    tiles, mt, nt = _split_tiles(a, tile)
    sched, _ = make_qr_graph(mt, nt, nr_queues=nr_queues)
    state = _TileState(tiles, backend)
    if mode == "sequential":
        SequentialExecutor(sched).run(state.exec_task)
    elif mode == "rounds":
        for rnd in conflict_rounds(sched, nr_lanes=max(nr_queues, 1)):
            _run_round_batched(state, sched, rnd)
    elif mode == "threaded":
        sched.run_threaded(nr_queues, state.exec_task)
    else:
        raise ValueError(mode)
    r = _assemble_r(state.tiles, mt, nt, tile, a.dtype)
    return r, sched


def _run_round_batched(state: _TileState, sched: QSched, rnd) -> None:
    """Execute one conflict-free round, batching same-type tasks with vmap
    (stack tiles → one batched kernel call → scatter back)."""
    by_type: Dict[int, list] = {}
    for tid in rnd.tasks:
        t = sched.tasks[tid]
        by_type.setdefault(t.type, []).append(t.data)
    tl = state.tiles
    for ttype, datas in by_type.items():
        if ttype == T_GEQRF or len(datas) == 1:
            for d in datas:
                state.exec_task(ttype, d)
            continue
        if ttype == T_LARFT:
            kk = jnp.stack([tl[k, k] for (k, j, _) in datas])
            tt = jnp.stack([state.t_diag[k] for (k, j, _) in datas])
            cc = jnp.stack([tl[k, j] for (k, j, _) in datas])
            out = jax.vmap(lambda a, b, c: ops.apply_qt(a, b, c,
                                                        backend=state.backend))(kk, tt, cc)
            for (k, j, _), o in zip(datas, out):
                tl[k, j] = o
        elif ttype == T_TSQRF:
            for d in datas:  # same-column TSQRFs conflict; cross-column batch
                state.exec_task(ttype, d)
        elif ttype == T_SSRFT:
            v2 = jnp.stack([tl[i, k] for (i, j, k) in datas])
            tt = jnp.stack([state.t_ts[i, k] for (i, j, k) in datas])
            c1 = jnp.stack([tl[k, j] for (i, j, k) in datas])
            c2 = jnp.stack([tl[i, j] for (i, j, k) in datas])
            o1, o2 = jax.vmap(lambda a, b, c, d: ops.apply_tsqt(
                a, b, c, d, backend=state.backend))(v2, tt, c1, c2)
            for (i, j, k), x1, x2 in zip(datas, o1, o2):
                tl[k, j] = x1
                tl[i, j] = x2


def paper_counts(mt: int = 32, nt: int = 32):
    """Structural counts for the paper's 2048² / 64² benchmark matrix."""
    s, _ = make_qr_graph(mt, nt)
    return {
        "tasks": s.nr_tasks,
        "deps": s.nr_deps,
        "resources": len(s.resources),
        "locks": s.nr_locks,
        "uses": s.nr_uses,
    }
