"""Chrome trace-event JSON export: every trace opens in Perfetto.

``to_chrome_trace`` renders a :class:`~repro.obs.trace.Tracer`'s records
as the Chrome trace-event format (the JSON dialect Perfetto and
``chrome://tracing`` both read natively):

* each distinct ``process`` label becomes one **pid track** — this is how
  simulator-*predicted* timelines (``core.simulator.timeline_to_tracer``)
  overlay *measured* engine/executor timelines in one view;
* **lanes are threads**: task records draw on ``tid = lane`` rows (the
  paper's per-thread task timelines, Figs 6/7/11/12), nested spans draw
  on their recording thread's row, and both get ``thread_name`` metadata;
* spans and task records are complete (``ph: "X"``) events whose nesting
  Perfetto derives from time containment;
* counter samples are ``ph: "C"`` events — Perfetto renders each name as
  a counter track (page-pool occupancy, queue depth);
* a final-value sample of a :class:`~repro.obs.metrics.MetricsRegistry`
  can be attached as trace-level metadata (``otherData``).

Timestamps are normalized to the earliest record and scaled to
microseconds (Chrome's unit).  ``validate_chrome_trace`` is the schema
check the tests and the CI trace-smoke step run against every produced
artifact; the module is runnable as a validator CLI:

    PYTHONPATH=src python -m repro.obs.export /tmp/trace.json
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple, Union

from .metrics import MetricsRegistry
from .trace import NullTracer, Tracer, get_tracer

_US = 1e6      # records hold seconds; Chrome wants microseconds


def _normalize_origin(tracer) -> float:
    ts = ([s.t0 for s in tracer.spans] + [t.t0 for t in tracer.tasks]
          + [c.t for c in tracer.counters])
    return min(ts) if ts else 0.0


class _Tracks:
    """pid/tid assignment: one pid per process label, one tid per
    (process, lane) pair, with metadata events naming both."""

    def __init__(self, events: List[Dict[str, Any]]):
        self.events = events
        self._pids: Dict[str, int] = {}
        self._tids: Dict[Tuple[str, Any], int] = {}

    def pid(self, process: str) -> int:
        p = self._pids.get(process)
        if p is None:
            p = self._pids[process] = len(self._pids) + 1
            self.events.append({
                "ph": "M", "name": "process_name", "pid": p, "tid": 0,
                "ts": 0, "args": {"name": process}})
        return p

    def tid(self, process: str, lane: Any, prefix: str = "lane") -> int:
        key = (process, lane)
        t = self._tids.get(key)
        if t is None:
            n = sum(1 for (pr, _) in self._tids if pr == process)
            t = self._tids[key] = n + 1
            self.events.append({
                "ph": "M", "name": "thread_name",
                "pid": self.pid(process), "tid": t, "ts": 0,
                "args": {"name": lane if isinstance(lane, str)
                         else f"{prefix} {lane}"}})
        return t


def to_chrome_trace(tracer: Optional[Union[Tracer, NullTracer]] = None, *,
                    registry: Optional[MetricsRegistry] = None,
                    type_names: Optional[Dict[int, str]] = None
                    ) -> Dict[str, Any]:
    """Render a tracer's records as a Chrome trace-event JSON object
    (default: the process-global tracer).  ``type_names`` maps task-type
    ints to display names on task events; ``registry`` attaches a final
    metrics snapshot as ``otherData``."""
    if tracer is None:
        tracer = get_tracer()
    events: List[Dict[str, Any]] = []
    tracks = _Tracks(events)
    t0 = _normalize_origin(tracer)

    for s in tracer.spans:
        events.append({
            "ph": "X", "name": s.name, "cat": "span",
            "pid": tracks.pid(s.process),
            "tid": tracks.tid(s.process, s.lane),
            "ts": (s.t0 - t0) * _US,
            "dur": max((s.t1 - s.t0) * _US, 0.0),
            "args": {k: _jsonable(v) for k, v in s.args.items()},
        })
    for t in tracer.tasks:
        tname = (type_names or {}).get(t.task_type, f"type {t.task_type}")
        events.append({
            "ph": "X", "name": t.name or tname, "cat": "task",
            "pid": tracks.pid(t.process),
            "tid": tracks.tid(t.process, t.lane),
            "ts": (t.t0 - t0) * _US,
            "dur": max((t.t1 - t.t0) * _US, 0.0),
            "args": {"tid": t.tid, "type": t.task_type, "lane": t.lane},
        })
    for c in tracer.counters:
        events.append({
            "ph": "C", "name": c.name, "cat": "metric",
            "pid": tracks.pid(c.process), "tid": 0,
            "ts": (c.t - t0) * _US,
            "args": {"value": c.value},
        })

    out: Dict[str, Any] = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
    }
    if registry is not None:
        out["otherData"] = {"metrics": registry.snapshot()}
    return out


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def write_chrome_trace(path: str,
                       tracer: Optional[Union[Tracer, NullTracer]] = None, *,
                       registry: Optional[MetricsRegistry] = None,
                       type_names: Optional[Dict[int, str]] = None
                       ) -> Dict[str, Any]:
    """Export, self-validate, and write one trace file (default: the
    process-global tracer).  Returns the validation summary (event counts
    per phase)."""
    obj = to_chrome_trace(tracer, registry=registry, type_names=type_names)
    summary = validate_chrome_trace(obj)
    with open(path, "w") as f:
        json.dump(obj, f)
    return summary


def validate_chrome_trace(obj: Union[Dict[str, Any], str]
                          ) -> Dict[str, Any]:
    """Schema check for Chrome trace-event JSON (object format).  Accepts
    a parsed dict or a file path; raises ``ValueError`` on the first
    violation; returns a summary with per-phase event counts, counter
    track names and process names."""
    if isinstance(obj, str):
        with open(obj) as f:
            obj = json.load(f)
    if not isinstance(obj, dict):
        raise ValueError("trace must be a JSON object with 'traceEvents'")
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    phases: Dict[str, int] = {}
    counter_tracks = set()
    processes = set()
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"event {i}: not an object")
        for k in ("ph", "name", "pid", "ts"):
            if k not in e:
                raise ValueError(f"event {i}: missing required key {k!r}")
        ph = e["ph"]
        if not isinstance(ph, str) or len(ph) != 1:
            raise ValueError(f"event {i}: bad phase {ph!r}")
        if not isinstance(e["name"], str):
            raise ValueError(f"event {i}: name must be a string")
        for k in ("pid", "ts"):
            if not isinstance(e[k], (int, float)) or isinstance(e[k], bool):
                raise ValueError(f"event {i}: {k} must be a number")
        if ph != "M" and e["ts"] < 0:
            raise ValueError(f"event {i}: negative timestamp {e['ts']}")
        if ph == "X":
            if "dur" not in e or not isinstance(e["dur"], (int, float)):
                raise ValueError(f"event {i}: X event needs numeric 'dur'")
            if e["dur"] < 0:
                raise ValueError(f"event {i}: negative duration {e['dur']}")
        if ph == "C":
            args = e.get("args")
            if (not isinstance(args, dict) or not args
                    or not all(isinstance(v, (int, float))
                               and not isinstance(v, bool)
                               for v in args.values())):
                raise ValueError(
                    f"event {i}: C event needs numeric args series")
            counter_tracks.add(e["name"])
        if ph == "M" and e["name"] == "process_name":
            processes.add(e.get("args", {}).get("name"))
        phases[ph] = phases.get(ph, 0) + 1
    return {
        "events": len(events),
        "phases": phases,
        "counter_tracks": sorted(counter_tracks),
        "processes": sorted(p for p in processes if p),
    }


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate a Chrome trace-event JSON file")
    ap.add_argument("paths", nargs="+")
    args = ap.parse_args(argv)
    for path in args.paths:
        summary = validate_chrome_trace(path)
        print(f"{path}: OK — {summary['events']} events, "
              f"phases={summary['phases']}, "
              f"processes={summary['processes']}, "
              f"counters={summary['counter_tracks']}")


if __name__ == "__main__":
    main()
