"""Metrics registry: counters, gauges, histograms with exact semantics.

The paper's overhead accounting (Fig 13: gettask calls, lock failures,
task counts per type) needs *exact integers*, not sampled approximations
— tests assert counts like "this QR plan executed exactly 5 SSRFT tasks"
and "this serving run retired exactly 5 requests".  So:

* :class:`Counter` — monotonically increasing exact int (``inc``
  under a lock; ``value`` is always the true count);
* :class:`Gauge` — last-written float (page-pool occupancy, queue depth);
* :class:`Histogram` — exact count/sum/min/max plus fixed-boundary
  bucket counts (TTFT / request latency distributions).

A :class:`MetricsRegistry` is a get-or-create namespace of metrics;
``snapshot()`` returns a plain dict for logging/JSON.  The process-global
default registry (``get_registry()``) is what the scheduler core records
to (plan-cache hits/misses, executor task counts, engine launches);
subsystems that need isolated accounting (``serve.GenerateService``) hold
their own registry instance.  Time-series *samples* of metric values are
the tracer's job (``Tracer.counter``) — this module stores only current
values.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class Counter:
    """Exact monotonically increasing integer counter."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r}: negative increment {n}")
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0


class Gauge:
    """Last-written value (occupancy, depth, temperature-style metrics)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, dv: float) -> None:
        with self._lock:
            self._value += float(dv)

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self.set(0.0)


class Histogram:
    """Exact count/sum/min/max plus cumulative-style bucket counts over
    fixed upper boundaries (``le``); values above the last boundary land
    in the overflow bucket.  Boundaries are per-histogram and fixed at
    creation, so two observations of the same value always count
    identically (exact accounting, no reservoir sampling)."""

    __slots__ = ("name", "buckets", "_counts", "_count", "_sum",
                 "_min", "_max", "_lock")

    def __init__(self, name: str,
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        bs = tuple(sorted(DEFAULT_BUCKETS if buckets is None else buckets))
        if not bs:
            raise ValueError(f"histogram {name!r}: need >= 1 bucket bound")
        self.buckets = bs
        self._counts = [0] * (len(bs) + 1)      # +1 overflow
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            i = 0
            for b in self.buckets:
                if v <= b:
                    break
                i += 1
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            if not self._count:
                return {"count": 0, "sum": 0.0}
            return {
                "count": self._count, "sum": self._sum,
                "min": self._min, "max": self._max,
                "mean": self._sum / self._count,
                "buckets": {**{f"le_{b:g}": c for b, c in
                               zip(self.buckets, self._counts)},
                            "overflow": self._counts[-1]},
            }

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.buckets) + 1)
            self._count = 0
            self._sum = 0.0
            self._min = float("inf")
            self._max = float("-inf")


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Get-or-create namespace of metrics.  A name is bound to one kind
    for the registry's lifetime — asking for an existing name with a
    different kind raises (silent kind-aliasing would corrupt counts)."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, *args) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, *args)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        h = self._get_or_create(name, Histogram, buckets)
        if buckets is not None and tuple(sorted(buckets)) != h.buckets:
            raise ValueError(
                f"histogram {name!r} already registered with different "
                f"bucket bounds")
        return h

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view: counters/gauges as their value, histograms as
        their summary dict."""
        with self._lock:
            items: List[Tuple[str, Metric]] = sorted(self._metrics.items())
        out: Dict[str, Any] = {}
        for name, m in items:
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        """Zero every metric (registrations are kept)."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()


_default = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global registry the scheduler core records to."""
    return _default
