"""repro.obs — task-level tracing, metrics, Perfetto export.

The observability tier under every other layer (DESIGN.md
§Observability): ``trace`` collects the paper's per-task tic/toc records
plus nested phase spans and counter samples, ``metrics`` keeps
exact-integer counters/gauges/histograms, and ``export`` renders both as
Chrome trace-event JSON for Perfetto / ``chrome://tracing``.  Depends on
nothing else in the repo, so ``core`` may import it freely.
"""

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry)
from .trace import (NullTracer, Tracer, disable, enable, get_tracer,
                    set_tracer, span)

_EXPORT_NAMES = ("to_chrome_trace", "validate_chrome_trace",
                 "write_chrome_trace")


def __getattr__(name):
    # lazy so `python -m repro.obs.export` doesn't import the submodule
    # twice (runpy warns when a package __init__ pre-imports its target)
    if name in _EXPORT_NAMES:
        from . import export
        return getattr(export, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "NullTracer", "Tracer", "disable", "enable", "get_tracer",
    "set_tracer", "span",
    "to_chrome_trace", "validate_chrome_trace", "write_chrome_trace",
]
