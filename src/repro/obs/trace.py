"""Task-level tracing: the paper's tic/toc instrumentation as a subsystem.

QuickSched's evaluation *is* an observability artifact — per-task
timestamps rendered as per-thread task timelines (Figs 6/7/11/12) plus
explicit scheduler-overhead accounting (Figs 8/13).  This module is the
single clock and record store behind that methodology for every tier of
the repo: a thread-safe :class:`Tracer` collecting

* **spans** — nested named intervals opened with ``with tracer.span(...)``
  (thread-local nesting, the scheduler's build/prepare/lower/encode
  phases, engine launch segments, serving request lifecycles), or
  recorded post-hoc with explicit timestamps via ``event_span`` (for
  intervals measured around blocking device calls or spanning multiple
  service ticks);
* **task records** — the paper's flat per-task tic/toc tuples
  ``(tid, task_type, lane, t0, t1)``: one per executed task, with the
  lane/worker as the timeline row (``ThreadedExecutor`` workers, engine
  measurement items, simulator lanes);
* **counter samples** — named time-series points (page-pool occupancy,
  queue depth) that export as Perfetto counter tracks.

Every record carries a ``process`` label; the Chrome exporter
(``repro.obs.export``) maps distinct labels to distinct pid tracks, which
is how simulator-*predicted* timelines overlay *measured* ones in a
single Perfetto view.

The process-global default tracer is a :class:`NullTracer` — a guaranteed
near-zero-overhead no-op (``span()`` returns one shared singleton context
manager, ``task``/``counter``/``event_span`` return immediately, and
``enabled`` is False so hot loops can skip even the timestamp reads).
``enable()`` swaps in a recording tracer; instrumentation sites never
need to know which is installed.  The tracing-disabled cost through the
scheduler hot path is gated ≤ 3% in ``benchmarks/sched_overhead.py``.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Union

now = time.perf_counter     # the one clock every record uses

DEFAULT_PROCESS = "measured"


@dataclass
class SpanRecord:
    """One closed interval.  ``lane`` is the timeline row label (thread
    name for nested spans, caller-chosen for ``event_span``); ``depth`` is
    the thread-local nesting depth at open time (1 = top level, 0 for
    explicit-timestamp spans, which carry no nesting)."""
    name: str
    t0: float
    t1: float
    lane: str
    depth: int
    process: str = DEFAULT_PROCESS
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass
class TaskRecord:
    """The paper's per-task tic/toc tuple: task ``tid`` of ``task_type``
    ran on ``lane`` (worker/thread/queue id) from ``t0`` to ``t1``."""
    tid: int
    task_type: int
    lane: int
    t0: float
    t1: float
    process: str = DEFAULT_PROCESS
    name: Optional[str] = None


@dataclass
class CounterSample:
    name: str
    t: float
    value: float
    process: str = DEFAULT_PROCESS


class _Span:
    """Context manager recording one nested span on exit.  ``args`` may be
    mutated inside the ``with`` block to attach results computed during
    the span (round counts, cache hits, ...)."""

    __slots__ = ("_tracer", "name", "args", "t0", "_lane", "_depth")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        tr = self._tracer
        stack = tr._stack()
        stack.append(self)
        self._depth = len(stack)
        self._lane = threading.current_thread().name
        self.t0 = now()
        return self

    def __exit__(self, *exc) -> None:
        t1 = now()
        tr = self._tracer
        tr._stack().pop()
        with tr._lock:
            tr.spans.append(SpanRecord(
                self.name, self.t0, t1, self._lane, self._depth,
                tr.process, self.args))


class _NullSpan:
    """Shared no-op span: one instance serves every disabled ``span()``
    call.  ``args`` assignments land in a throwaway class dict that is
    never read (the record is never stored)."""

    __slots__ = ()
    name = ""
    args: Dict[str, Any] = {}

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe trace record store.  All three record kinds append
    under one lock; reads (the exporter, tests) take snapshots via the
    plain list attributes after the traced region has quiesced."""

    enabled = True

    def __init__(self, process: str = DEFAULT_PROCESS):
        self.process = process
        self.spans: List[SpanRecord] = []
        self.tasks: List[TaskRecord] = []
        self.counters: List[CounterSample] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self.t_start = now()

    def _stack(self) -> List[_Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **args: Any) -> _Span:
        """Open a nested span: ``with tracer.span("plan.lower", tasks=n):``.
        Nesting is per-thread; the record is appended when the block
        exits."""
        return _Span(self, name, args)

    def event_span(self, name: str, t0: float, t1: float, *,
                   lane: str = "events", process: Optional[str] = None,
                   **args: Any) -> None:
        """Record a span with explicit timestamps (no thread-local
        nesting) — intervals measured around blocking device calls or
        assembled after the fact (request lifecycles)."""
        with self._lock:
            self.spans.append(SpanRecord(
                name, float(t0), float(t1), lane, 0,
                process or self.process, args))

    def task(self, tid: int, task_type: int, lane: int, t0: float,
             t1: float, *, process: Optional[str] = None,
             name: Optional[str] = None) -> None:
        """Record one task execution — the paper's tic/toc tuple."""
        with self._lock:
            self.tasks.append(TaskRecord(
                int(tid), int(task_type), int(lane), float(t0), float(t1),
                process or self.process, name))

    def counter(self, name: str, value: float, t: Optional[float] = None, *,
                process: Optional[str] = None) -> None:
        """Record one sample of a named time-series (exports as a Perfetto
        counter track)."""
        with self._lock:
            self.counters.append(CounterSample(
                name, now() if t is None else float(t), float(value),
                process or self.process))

    # -- introspection ------------------------------------------------------
    @property
    def nr_records(self) -> int:
        return len(self.spans) + len(self.tasks) + len(self.counters)

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.tasks.clear()
            self.counters.clear()
            self.t_start = now()


class NullTracer:
    """Disabled tracer: every entry point is a constant-time no-op and
    ``span()`` always returns the same shared singleton, so instrumented
    code paths pay only a method call when tracing is off (gated ≤ 3% on
    the scheduler hot path by ``benchmarks/sched_overhead.py``)."""

    enabled = False
    process = DEFAULT_PROCESS
    spans: List[SpanRecord] = []      # class-level, never appended to
    tasks: List[TaskRecord] = []
    counters: List[CounterSample] = []

    def span(self, name: str, **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def event_span(self, name: str, t0: float, t1: float, **kw: Any) -> None:
        pass

    def task(self, tid: int, task_type: int, lane: int, t0: float,
             t1: float, **kw: Any) -> None:
        pass

    def counter(self, name: str, value: float,
                t: Optional[float] = None, **kw: Any) -> None:
        pass

    @property
    def nr_records(self) -> int:
        return 0

    def clear(self) -> None:
        pass


_NULL = NullTracer()
_default: Union[Tracer, NullTracer] = _NULL


def get_tracer() -> Union[Tracer, NullTracer]:
    """The process-global tracer every instrumentation site records to."""
    return _default


def set_tracer(tracer: Union[Tracer, NullTracer]) -> Union[Tracer, NullTracer]:
    global _default
    _default = tracer
    return tracer


def enable(process: str = DEFAULT_PROCESS) -> Tracer:
    """Install (and return) a fresh recording tracer as the global
    default."""
    return set_tracer(Tracer(process))


def disable() -> None:
    """Restore the no-op default."""
    set_tracer(_NULL)


def span(name: str, **args: Any):
    """Module-level convenience: open a span on the global tracer."""
    return _default.span(name, **args)
