"""Distributed substrate: sharding specs, activation-sharding constraints,
compressed data-parallel all-reduce, and ring collective matmuls.

Layout (DESIGN.md §Distributed):
  act_sharding — logical ("dp"/"tp") activation constraints, no-op outside
                 an ``activation_sharding`` context so model code stays
                 mesh-agnostic;
  sharding     — PartitionSpec derivation for parameter / optimizer /
                 batch / KV-cache pytrees over the launch/mesh.py meshes;
  compression  — int8 gradient all-reduce with error feedback (EF-SGD);
  collective   — allgather/reduce-scatter matmuls as ``ppermute`` rings
                 that overlap per-shard matmuls with neighbour exchange.
"""

from .act_sharding import activation_sharding, constrain
from .collective import allgather_matmul, reducescatter_matmul
from .compression import (compressed_psum, dequantize_int8,
                          init_error_feedback, quantize_int8)
from .sharding import (batch_pspecs, cache_pspecs, opt_pspecs, param_pspecs,
                       shardings_for)

__all__ = [
    "activation_sharding", "constrain",
    "param_pspecs", "opt_pspecs", "batch_pspecs", "cache_pspecs",
    "shardings_for",
    "quantize_int8", "dequantize_int8", "init_error_feedback",
    "compressed_psum",
    "allgather_matmul", "reducescatter_matmul",
]
