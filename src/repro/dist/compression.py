"""Int8 gradient all-reduce with error feedback (DESIGN.md §Distributed).

``compressed_psum`` implements EF-SGD compression for the data-parallel
gradient reduction: each shard quantizes (gradient + carried residual) to
int8 with one fp32 scale per leaf, the int8 payloads and scales are
all-gathered across the DP axis — so the wire carries 1-byte elements plus
one scalar per (shard, leaf), a 4× payload cut against an fp32 ring
all-reduce — and each shard dequantizes and sums locally.  The local
quantization residual is carried into the next step, keeping the
*accumulated* update unbiased: summing the outputs over time telescopes to
the true gradient sum minus the (bounded) final residual, which is the
convergence property tests/test_dist.py checks.  (A requantizing ring that
restores O(1)-per-hop bytes at large DP degrees is future work — the
Pallas RDMA ring pattern; the semantics here are its reference.)
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization: returns (q, scale) with
    ``x ≈ q * scale``, ``q ∈ [-127, 127]`` and absolute error ≤ scale/2."""
    xf = x.astype(jnp.float32)
    smax = jnp.max(jnp.abs(xf))
    scale = jnp.where(smax > 0, smax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(tree: Pytree) -> Pytree:
    """Zero residuals, fp32, one per gradient leaf."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compressed_psum(grads: Pytree, ef: Pytree,
                    axis_name: Optional[str] = None
                    ) -> Tuple[Pytree, Pytree]:
    """Quantized psum with error feedback.

    Per leaf: ``c = g + ef``; ``c`` is int8-quantized and ``(q, scale)`` is
    what crosses the wire — all-gathered over ``axis_name`` and
    dequantize-summed locally on every shard (when ``axis_name`` is None the
    shard's own dequantized value is returned: the single-device / unit-test
    path).  ``ef' = c - deq(q(c))`` stays local.  Invariant: each shard's
    contribution to the sum plus its ``ef'`` equals its ``g + ef`` exactly,
    so the residual never escapes and accumulated updates converge to the
    true sum.

    Returns ``(summed_tree, new_ef_tree)``.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    assert len(flat_g) == len(flat_e), "grads/ef tree mismatch"
    outs, resids = [], []
    for g, e in zip(flat_g, flat_e):
        c = g.astype(jnp.float32) + e
        q, scale = quantize_int8(c)
        resids.append(c - dequantize_int8(q, scale))
        if axis_name is None:
            outs.append(dequantize_int8(q, scale))
        else:
            q_all = jax.lax.all_gather(q, axis_name)       # int8 on the wire
            s_all = jax.lax.all_gather(scale, axis_name)   # one fp32 / shard
            outs.append(jnp.sum(
                q_all.astype(jnp.float32)
                * s_all.reshape((-1,) + (1,) * q.ndim), axis=0))
    return treedef.unflatten(outs), treedef.unflatten(resids)
