"""Ring collective matmuls (DESIGN.md §Distributed).

Instead of ``all_gather → matmul`` / ``matmul → reduce_scatter`` — which
serialize a full-size collective against a full-size matmul — these run the
collective as ``axis_size`` ring steps of ``jax.lax.ppermute``, each step
paired with the per-shard matmul for the block in flight.  XLA can then
overlap step i's neighbour exchange with step i's (or i±1's) matmul, the
communication/computation-overlap structure of Bak et al.'s task-graph
scheduling extensions, expressed at the JAX level.  Both functions are
called per-shard, inside ``jax.shard_map`` over the TP axis, and are exact
(no approximation): tests/test_dist.py checks them against ``x @ w`` under
8 forced host devices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _ring_perm(axis_size: int):
    """Forward ring: shard j sends to shard j+1 (mod axis_size)."""
    return [(j, (j + 1) % axis_size) for j in range(axis_size)]


def allgather_matmul(x_local: jnp.ndarray, w: jnp.ndarray,
                     axis_name: str, axis_size: int) -> jnp.ndarray:
    """Overlapped ``all_gather(x) @ w``.

    ``x_local``: this shard's ``(m / axis_size, k)`` rows of x;
    ``w``: the replicated ``(k, n)`` weight.
    Returns the full ``(m, n)`` product on every shard.  Step i multiplies
    the x block that originated on shard ``(idx - i) % axis_size`` while the
    ring permute moves the blocks one hop forward.
    """
    m_loc = x_local.shape[0]
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros((m_loc * axis_size, w.shape[1]),
                    jnp.promote_types(x_local.dtype, w.dtype))
    perm = _ring_perm(axis_size)
    chunk = x_local
    for i in range(axis_size):
        src = jnp.mod(idx - i, axis_size)          # block's origin shard
        out = jax.lax.dynamic_update_slice(
            out, (chunk @ w).astype(out.dtype), (src * m_loc, 0))
        if i + 1 < axis_size:
            chunk = jax.lax.ppermute(chunk, axis_name, perm)
    return out


def reducescatter_matmul(x_local: jnp.ndarray, w_local: jnp.ndarray,
                         axis_name: str, axis_size: int) -> jnp.ndarray:
    """Overlapped ``reduce_scatter(x @ w)`` over contracted shards.

    ``x_local``: ``(m, k / axis_size)`` column shard of x;
    ``w_local``: ``(k / axis_size, n)`` row shard of w.
    Returns this shard's ``(m / axis_size, n)`` rows of ``x @ w``.

    A travelling partial-sum ring: the accumulator initiated on shard d is
    destined for shard ``d - 1``'s output rows and arrives there after
    ``axis_size - 1`` hops, each host adding its own shard's contribution
    (an ``(m/axis_size, k/axis_size) @ (k/axis_size, n)`` matmul) for the
    block currently in flight — so every hop's transfer overlaps a block
    matmul instead of waiting for the full ``(m, n)`` partial product.
    """
    m, _ = x_local.shape
    assert m % axis_size == 0, (m, axis_size)
    m_loc = m // axis_size
    idx = jax.lax.axis_index(axis_name)
    perm = _ring_perm(axis_size)

    def block_partial(b):
        rows = jax.lax.dynamic_slice(
            x_local, (b * m_loc, 0), (m_loc, x_local.shape[1]))
        return (rows @ w_local).astype(jnp.float32)

    acc = block_partial(jnp.mod(idx - 1, axis_size))
    for i in range(1, axis_size):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + block_partial(jnp.mod(idx - i - 1, axis_size))
    return acc.astype(x_local.dtype)
