"""Logical→physical activation-sharding constraints (DESIGN.md §Distributed).

Model code annotates intermediates with *logical* axis names,

    x = constrain(x, "dp", None, "tp", None)

never with mesh axis names.  Outside an ``activation_sharding`` context the
call returns ``x`` untouched, so the exact same model code runs unsharded in
the CPU smoke tests.  Inside the context each logical name resolves to the
mesh axes the launcher chose — e.g. ``"dp"`` → ``("pod", "data")`` on the
multi-pod mesh, ``"tp"`` → ``"model"`` — and the entry becomes a
``with_sharding_constraint`` against the ambient mesh:

    with mesh, activation_sharding(("pod", "data"), "model"):
        lowered = fn.lower(*args)        # launch/dryrun.py --act-shard

Entries whose dimension does not divide evenly over the resolved axes are
dropped (replicated) instead of failing the lower, so one annotation serves
every (config × mesh) cell of the dry-run grid.
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Dict, Optional, Tuple, Union

import jax

Axes = Union[str, Tuple[str, ...], None]

_MAPPING: ContextVar[Optional[Dict[str, Axes]]] = ContextVar(
    "activation_sharding_mapping", default=None)


@contextlib.contextmanager
def activation_sharding(dp: Axes = "data", tp: Axes = "model"):
    """Activate ``constrain`` with the given logical→mesh axis mapping."""
    token = _MAPPING.set({"dp": dp, "tp": tp})
    try:
        yield
    finally:
        _MAPPING.reset(token)


def _ambient_mesh():
    """The mesh installed by ``with mesh:`` around the current trace.

    Resolved through the thread-resources env (private in jax 0.4.x, tried
    under both historical homes).  If neither path exists on some future
    jax, constrain degrades to a no-op — tests/test_sharding_specs.py
    asserts against the lowered HLO that constraints actually land, so the
    degradation is loud, not silent.
    """
    for locate in (
        lambda: __import__("jax._src.mesh", fromlist=["thread_resources"])
                .thread_resources,
        lambda: __import__("jax.interpreters.pxla", fromlist=["pxla"])
                .thread_resources,
    ):
        try:
            m = locate().env.physical_mesh
            return None if m.empty else m
        except Exception:
            continue
    return None


def _as_tuple(axes: Axes) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def constrain(x, *spec):
    """``with_sharding_constraint`` over logical axes; no-op outside an
    ``activation_sharding`` context or a ``with mesh:`` block.

    ``spec`` entries are ``"dp"``, ``"tp"``, a raw mesh axis name, or
    ``None`` (replicated); trailing dims may be omitted.
    """
    mapping = _MAPPING.get()
    if mapping is None:
        return x
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    resolved = []
    for dim, entry in zip(x.shape, spec):
        axes = mapping.get(entry, entry) if entry is not None else None
        if axes is None:
            resolved.append(None)
            continue
        names = _as_tuple(axes)
        if any(a not in mesh.shape for a in names):
            resolved.append(None)
            continue
        size = 1
        for a in names:
            size *= mesh.shape[a]
        resolved.append(axes if dim % size == 0 else None)
    pspec = jax.sharding.PartitionSpec(*resolved)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, pspec))
