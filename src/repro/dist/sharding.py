"""PartitionSpec derivation for params / optimizer / batch / cache pytrees
(DESIGN.md §Distributed).

One deterministic, shape-driven rule per pytree kind, over the meshes from
``launch/mesh.py`` (single-pod ``("data", "model")``, multi-pod
``("pod", "data", "model")``):

  params    — last dim → "model" (tensor parallel), second-to-last dim →
              "data" (FSDP); only the last two dims are ever candidates,
              so the leading dim of rank-≥3 scanned stacks stays
              replicated.  "pod" is pure data parallelism: parameters are
              replicated across pods.
  optimizer — the same rule on each state leaf.  Adam moments mirror the
              parameter shapes, so they inherit the parameter specs by
              construction (ZeRO: the FSDP axis shards them with the
              weights); factored Adafactor statistics and the scalar step
              counter get their own spec from their own shapes.
  batch     — dim 0 (global batch) → the DP axes; everything else
              replicated.
  cache     — dim 1 (batch; dim 0 is the scanned layer/site stack) → the
              DP axes; the KV-heads dim when present and divisible, else
              the last (head/latent/channel) dim → "model".

Every rule drops an axis whose size does not divide the dim, so any
(config × shape × mesh) cell of the dry-run grid lowers without resharding
errors — uneven cells degrade to replication, never to failure.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

Pytree = Any


def _dp_axes(mesh, multi_pod: bool):
    """The data-parallel axes and their total size."""
    names = ("pod", "data") if multi_pod and "pod" in mesh.shape else ("data",)
    size = 1
    for a in names:
        size *= mesh.shape[a]
    return (names if len(names) > 1 else names[0]), size


def _weight_spec(shape: Tuple[int, ...], mesh) -> P:
    spec = [None] * len(shape)
    if len(shape) >= 1 and shape[-1] % mesh.shape["model"] == 0:
        spec[-1] = "model"
    if len(shape) >= 2 and shape[-2] % mesh.shape["data"] == 0:
        spec[-2] = "data"
    return P(*spec)


def param_pspecs(params: Pytree, mesh, multi_pod: bool = False) -> Pytree:
    """Specs for a parameter pytree (leaves: arrays or ShapeDtypeStructs)."""
    del multi_pod  # parameters are pod-replicated; "pod" is pure DP
    return jax.tree.map(lambda l: _weight_spec(l.shape, mesh), params)


def opt_pspecs(pspecs: Pytree, opt_state: Pytree, mesh) -> Pytree:
    """Specs for an optimizer-state pytree (``OptState`` or any pytree).

    ``pspecs`` (the parameter specs) documents the contract: the rule is a
    pure function of leaf shape, so exact-shape moment tensors (AdamW m/v)
    receive identical specs to their parameters without any tree alignment.
    """
    del pspecs
    return jax.tree.map(lambda l: _weight_spec(l.shape, mesh), opt_state)


def batch_pspecs(batch: Pytree, mesh, multi_pod: bool = False) -> Pytree:
    """Specs for model-input pytrees: dim 0 over the DP axes when even."""
    dp, size = _dp_axes(mesh, multi_pod)

    def rule(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and leaf.shape[0] % size == 0:
            spec[0] = dp
        return P(*spec)

    return jax.tree.map(rule, batch)


def cache_pspecs(cache: Pytree, cfg, mesh, multi_pod: bool = False) -> Pytree:
    """Specs for serving caches (KV / SSM state, see models/serving.py).

    Every cache leaf is layer-stacked: dim 0 is the scanned stack (never
    sharded), dim 1 the batch.  ``cfg`` selects the TP dim: the KV-heads
    dim for attention caches when it divides "model", else the trailing
    head/latent/channel dim.
    """
    dp, size = _dp_axes(mesh, multi_pod)
    model = mesh.shape["model"]
    kv_heads = {h for h in (cfg.n_kv_heads, cfg.n_heads) if h}

    def rule(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % size == 0:
            spec[1] = dp
        if (len(shape) >= 4 and shape[-2] in kv_heads
                and shape[-2] % model == 0):
            spec[-2] = "model"
        elif len(shape) >= 3 and shape[-1] % model == 0:
            spec[-1] = "model"
        return P(*spec)

    return jax.tree.map(rule, cache)


def shardings_for(pspecs: Pytree, mesh) -> Pytree:
    """PartitionSpec pytree → NamedSharding pytree over ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
