"""jax forward-compat shim, auto-imported by the ``site`` machinery for any
interpreter launched with this directory on PYTHONPATH — i.e. every process
under the tier-1 command (``PYTHONPATH=src python -m pytest ...``),
*including* the 8-forced-host-device subprocesses of tests/test_dist.py and
tests/test_dryrun_small.py, which is the point: those subprocesses do
``from jax import shard_map`` before importing anything of ours.

The pinned jax is 0.4.x, where ``shard_map`` still lives in
``jax.experimental.shard_map`` and spells the replication check
``check_rep`` (modern jax: ``jax.shard_map(..., check_vma=...)``).  A lazy
meta-path hook patches the installed jax right after its import completes;
on a jax new enough to export ``jax.shard_map`` natively the hook is a
no-op.  Nothing is imported eagerly, so interpreter startup cost is zero
for processes that never touch jax.
"""

import importlib.abc
import importlib.util
import sys


def _patch_jax(jax_mod):
    if getattr(jax_mod, "shard_map", None) is not None:
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                  check_rep=True, check_vma=None, auto=frozenset()):
        if check_vma is not None:
            check_rep = check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep,
                          auto=auto)

    jax_mod.shard_map = shard_map


class _JaxCompatFinder(importlib.abc.MetaPathFinder):
    def find_spec(self, fullname, path=None, target=None):
        if fullname != "jax":
            return None
        try:
            sys.meta_path.remove(self)      # run once; avoid re-entry below
        except ValueError:
            return None
        spec = importlib.util.find_spec("jax")
        if spec is None or spec.loader is None:
            return spec
        loader = spec.loader
        orig_exec = loader.exec_module

        def exec_module(module):
            orig_exec(module)
            try:
                _patch_jax(module)
            except Exception:
                pass                         # never break jax import

        loader.exec_module = exec_module
        return spec


def _chain_shadowed_sitecustomize():
    """Being first on sys.path shadows any environment-level sitecustomize
    (venv/conda/distro hooks); import whatever we shadowed so those still
    run — this module must be additive, never a replacement."""
    import importlib.machinery
    import os
    here = os.path.dirname(os.path.abspath(__file__))
    paths = [p for p in sys.path
             if os.path.abspath(p or os.getcwd()) != here]
    spec = importlib.machinery.PathFinder.find_spec("sitecustomize", paths)
    if spec is not None and spec.loader is not None:
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)


if "jax" in sys.modules:
    try:
        _patch_jax(sys.modules["jax"])
    except Exception:
        pass
else:
    sys.meta_path.insert(0, _JaxCompatFinder())

try:
    _chain_shadowed_sitecustomize()
except Exception:
    pass
