"""Paper Fig 8 + §4.1 table: tiled-QR strong scaling and structural counts.

Scheduler-limited scaling from the discrete-event engine driving the real
scheduler code path (DESIGN.md §2: wall-clock 64-core scaling is not
measurable on this 1-core container; the simulator uses the paper's own
asymptotic task costs).  Paper: 73% parallel efficiency at 64 cores
(including hardware effects)."""

from __future__ import annotations

import time

from repro.apps import qr
from repro.core import simulate

from .common import SMOKE, emit, time_us


def main() -> None:
    mt = 16 if SMOKE else 32         # the paper's grid is 32×32 tiles
    if mt == 32:
        counts = qr.paper_counts(mt, mt)
        emit("qr_tasks", 0, f"count={counts['tasks']} (paper 11440)")
        emit("qr_resources", 0, f"count={counts['resources']} (paper 1024)")
        emit("qr_locks", 0, f"count={counts['locks']} (paper 21856)")
        emit("qr_uses", 0, f"count={counts['uses']} (paper 11408)")
        emit("qr_deps", 0,
             f"count={counts['deps']} (paper 21824; see EXPERIMENTS.md)")

    t0 = time.perf_counter()
    s, _ = qr.make_qr_graph(mt, mt)
    build_us = (time.perf_counter() - t0) * 1e6
    emit("qr_graph_build", build_us, f"tasks={s.nr_tasks}")

    r1 = simulate(make(1, mt), 1)
    t1 = r1.makespan
    for n in (1, 4, 16, 64) if SMOKE else (1, 2, 4, 8, 16, 32, 64):
        t0 = time.perf_counter()
        r = simulate(make(n, mt), n)
        sim_us = (time.perf_counter() - t0) * 1e6
        eff = t1 / (n * r.makespan)
        emit(f"qr_scaling_{n:02d}", sim_us,
             f"speedup={t1 / r.makespan:.2f} efficiency={eff:.3f}")


def make(n: int, mt: int):
    s, _ = qr.make_qr_graph(mt, mt, nr_queues=n)
    return s


if __name__ == "__main__":
    main()
