"""Scheduler-core overhead trajectory: graph build / prepare / lowering
throughput (tasks/sec), array-native core vs the pre-refactor per-task
dataclass core, on the paper's QR 32×32 graph (11 440 tasks) and a
Barnes-Hut graph.  Writes ``BENCH_sched.json`` at the repo root.

The ``_Legacy*`` classes below are a faithful copy of the pre-refactor
build + prepare + conflict_rounds path (per-task dataclasses,
list-of-lists adjacency, per-round lock-manager objects); the reference
``weights.critical_path_weights`` and ``SeqLockManager`` they call are the
unchanged originals.  The build phase compares each core's *shipped*
builder: the legacy per-call ``addtask``/``addlock``/``addunlock`` loop
(the pre-refactor ``make_qr_graph``) vs the array core's bulk vectorized
``make_qr_graph`` — the build speedup therefore includes the bulk-API
win, not just cheaper per-call primitives.
"""

from __future__ import annotations

import json
import pathlib
import time
from dataclasses import dataclass, field
from typing import Any, List

import numpy as np

from repro.core import lower
from repro.core.locks import SeqLockManager
from repro.core import plan as plan_mod
from repro.core.plan import clear_plan_cache
from repro.core.weights import critical_path_weights
from repro.apps import qr

from .common import emit

REPEAT = 5


# --------------------------------------------------------------------------
# pre-refactor core (faithful copy: dataclass tasks, list adjacency)
# --------------------------------------------------------------------------

@dataclass
class _LegacyTask:
    tid: int
    type: int
    data: Any
    cost: float
    flags: int = 0
    unlocks: List[int] = field(default_factory=list)
    locks: List[int] = field(default_factory=list)
    uses: List[int] = field(default_factory=list)
    wait: int = 0
    weight: float = 0.0


@dataclass
class _LegacyResource:
    rid: int
    parent: int = -1
    owner: int = -1


class _LegacySched:
    def __init__(self):
        self.tasks: List[_LegacyTask] = []
        self.resources: List[_LegacyResource] = []

    def addtask(self, type=0, data=None, cost=1.0, flags=0):
        tid = len(self.tasks)
        self.tasks.append(_LegacyTask(tid, type, data, float(cost), flags))
        return tid

    def addres(self, owner=-1, parent=-1):
        rid = len(self.resources)
        self.resources.append(_LegacyResource(rid, parent, owner))
        return rid

    def addlock(self, t, r):
        self.tasks[t].locks.append(r)

    def adduse(self, t, r):
        self.tasks[t].uses.append(r)

    def addunlock(self, ta, tb):
        self.tasks[ta].unlocks.append(tb)

    def prepare(self):
        n = len(self.tasks)
        unlocks = [t.unlocks for t in self.tasks]
        costs = [t.cost for t in self.tasks]
        weights, order = critical_path_weights(n, unlocks, costs)
        for t, w in zip(self.tasks, weights):
            t.weight = w
            t.wait = 0
            t.locks.sort()
        for t in self.tasks:
            for j in t.unlocks:
                self.tasks[j].wait += 1
        self.topo_order = order

    def conflict_rounds(self, nr_lanes):
        tasks = self.tasks
        n = len(tasks)
        wait = [0] * n
        for t in tasks:
            for j in t.unlocks:
                wait[j] += 1
        ready = sorted((i for i in range(n) if wait[i] == 0),
                       key=lambda i: -tasks[i].weight)
        parents = [r.parent for r in self.resources]
        owners = [r.owner for r in self.resources]
        rounds = []
        done = 0
        while done < n:
            lm = SeqLockManager(parents)
            chosen, skipped = [], []
            for tid in ready:
                if lm.lock_all(tasks[tid].locks):
                    chosen.append(tid)
                else:
                    skipped.append(tid)
            if not chosen:
                raise RuntimeError("stalled")
            load = [0.0] * nr_lanes
            lanes = {l: [] for l in range(nr_lanes)}
            for tid in sorted(chosen, key=lambda i: -tasks[i].weight):
                lane = -1
                for r in tasks[tid].locks + tasks[tid].uses:
                    o = owners[r]
                    if o != -1 and 0 <= o < nr_lanes:
                        lane = o
                        break
                least = min(range(nr_lanes), key=lambda l: load[l])
                if lane == -1 or load[lane] > 2.0 * max(load[least], 1e-12) + 1e-12:
                    lane = least
                lanes[lane].append(tid)
                load[lane] += tasks[tid].cost
                for r in tasks[tid].locks + tasks[tid].uses:
                    owners[r] = lane
            rounds.append((chosen, lanes))
            done += len(chosen)
            newly = []
            for tid in chosen:
                for j in tasks[tid].unlocks:
                    wait[j] -= 1
                    if wait[j] == 0:
                        newly.append(j)
            ready = sorted(skipped + newly, key=lambda i: -tasks[i].weight)
        return rounds


def _legacy_qr_graph(mt, nt, nr_queues=1):
    """The pre-refactor make_qr_graph loop, driving the legacy core."""
    s = _LegacySched()
    ntiles = mt * nt
    rid = {}
    for j in range(nt):
        for i in range(mt):
            rid[i, j] = s.addres(owner=(j * mt + i) * nr_queues // ntiles)
    tid = {}
    for k in range(min(mt, nt)):
        t = s.addtask(qr.T_GEQRF, data=(k, k, k), cost=qr.COSTS[qr.T_GEQRF])
        s.addlock(t, rid[k, k])
        if (k, k) in tid:
            s.addunlock(tid[k, k], t)
        tid[k, k] = t
        for j in range(k + 1, nt):
            t = s.addtask(qr.T_LARFT, data=(k, j, k), cost=qr.COSTS[qr.T_LARFT])
            s.adduse(t, rid[k, k])
            s.adduse(t, rid[k, j])
            s.addunlock(tid[k, k], t)
            if (k, j) in tid:
                s.addunlock(tid[k, j], t)
            tid[k, j] = t
        for i in range(k + 1, mt):
            t = s.addtask(qr.T_TSQRF, data=(i, k, k), cost=qr.COSTS[qr.T_TSQRF])
            s.addlock(t, rid[i, k])
            s.addlock(t, rid[k, k])
            s.addunlock(tid[i - 1, k], t)
            if (i, k) in tid:
                s.addunlock(tid[i, k], t)
            tid[i, k] = t
            for j in range(k + 1, nt):
                t = s.addtask(qr.T_SSRFT, data=(i, j, k),
                              cost=qr.COSTS[qr.T_SSRFT])
                s.addlock(t, rid[i, j])
                s.addlock(t, rid[k, j])
                s.adduse(t, rid[i, k])
                s.addunlock(tid[i, k], t)
                s.addunlock(tid[i - 1, j], t)
                if (i, j) in tid:
                    s.addunlock(tid[i, j], t)
                tid[i, j] = t
    return s


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------

def _best(setup, timed, repeat=REPEAT):
    """(best wall seconds, last result) — each repeat times ``timed`` on a
    FRESH ``setup()`` state (no warm structure caches), best-of-N to cut
    scheduler/GC noise identically for both cores."""
    best, out = float("inf"), None
    for _ in range(repeat):
        st = setup()
        t0 = time.perf_counter()
        out = timed(st)
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_qr(mt=32, nt=32, nr_lanes=64):
    # one queue per lane — the paper's one-queue-per-core configuration
    nq = nr_lanes

    # legacy: build -> prepare -> conflict_rounds
    b_legacy, s_legacy = _best(
        lambda: None, lambda _: _legacy_qr_graph(mt, nt, nq))
    p_legacy, _ = _best(
        lambda: _legacy_qr_graph(mt, nt, nq), lambda s: s.prepare())

    def setup_legacy_prepared():
        s = _legacy_qr_graph(mt, nt, nq)
        s.prepare()
        return s
    l_legacy, rounds_legacy = _best(
        setup_legacy_prepared, lambda s: s.conflict_rounds(nr_lanes))

    # array core: vectorized build -> compiled prepare -> plan lowering
    b_new, s_new = _best(
        lambda: None, lambda _: qr.make_qr_graph(mt, nt, nr_queues=nq)[0])
    p_new, _ = _best(
        lambda: qr.make_qr_graph(mt, nt, nr_queues=nq)[0],
        lambda s: s.prepare())

    def setup_array_prepared():
        s, _ = qr.make_qr_graph(mt, nt, nr_queues=nq)
        s.prepare()
        clear_plan_cache()
        return s
    l_new, plan = _best(setup_array_prepared,
                        lambda s: lower(s, nr_lanes, cache=False))
    s_new.prepare()
    lower(s_new, nr_lanes)                            # populate the cache
    c_new, _ = _best(lambda: s_new, lambda s: lower(s, nr_lanes))

    n = s_new.nr_tasks
    assert n == len(s_legacy.tasks)
    # QR levels are conflict-free, so both greedy constructions emit the
    # Kahn levels and the round counts must agree (on graphs with
    # intra-level conflicts the packings may legitimately differ).
    assert len(plan.rounds) == len(rounds_legacy), "round structure diverged"
    total_legacy = b_legacy + p_legacy + l_legacy
    total_new = b_new + p_new + l_new
    return {
        "graph": f"qr_{mt}x{nt}",
        "tasks": n,
        "deps": s_new.nr_deps,
        "nr_lanes": nr_lanes,
        "rounds": len(plan.rounds),
        "legacy_s": {"build": b_legacy, "prepare": p_legacy,
                     "lower": l_legacy, "total": total_legacy},
        "array_s": {"build": b_new, "prepare": p_new, "lower": l_new,
                    "total": total_new, "lower_cached": c_new},
        "tasks_per_sec": {"legacy": n / total_legacy,
                          "array": n / total_new},
        "speedup": {"build": b_legacy / b_new,
                    "prepare": p_legacy / p_new,
                    "lower": l_legacy / l_new,
                    "total": total_legacy / total_new},
    }


def bench_bh(n_particles=20000):
    from repro.apps import barneshut as bh
    rng = np.random.default_rng(11)
    x, m = rng.random((n_particles, 3)), rng.random(n_particles) + 0.5
    tree = bh.Octree(x, m, n_max=64)
    b, g = _best(lambda: None,
                 lambda _: bh.build_graph(tree, n_task=256, nr_queues=8),
                 repeat=3)
    s = g.sched
    p, _ = _best(lambda: bh.build_graph(tree, n_task=256, nr_queues=8).sched,
                 lambda ss: ss.prepare(), repeat=3)

    def setup_prepared():
        s.prepare()
        clear_plan_cache()
        return s
    l, plan = _best(setup_prepared, lambda ss: lower(ss, 8, cache=False),
                    repeat=3)
    return {
        "graph": f"bh_{n_particles}",
        "tasks": s.nr_tasks,
        "rounds": len(plan.rounds),
        "array_s": {"build": b, "prepare": p, "lower": l,
                    "total": b + p + l},
        "tasks_per_sec": {"array": s.nr_tasks / (b + p + l)},
    }


def bench_obs_overhead(mt=32, nt=32, nr_lanes=64, repeat=9):
    """Tracing-*disabled* observability cost on the scheduler hot path
    (DESIGN.md §Observability, gated ≤ 3% in CI with an absolute floor
    for timer noise): the shipped instrumented ``lower`` — null-tracer
    spans plus registry counters — vs calling the raw ``_lower`` body
    directly, i.e. the same work with every instrumentation site
    bypassed.  Both run uncached on a fresh prepared graph per repeat."""
    from repro.obs import trace as obs_trace

    assert not obs_trace.get_tracer().enabled, \
        "obs overhead must be measured with tracing disabled"

    def setup():
        s, _ = qr.make_qr_graph(mt, nt, nr_queues=nr_lanes)
        s.prepare()
        clear_plan_cache()
        return s

    instr, _ = _best(setup, lambda s: lower(s, nr_lanes, cache=False),
                     repeat=repeat)
    bare, _ = _best(setup, lambda s: plan_mod._lower(s, nr_lanes, None, ""),
                    repeat=repeat)
    return {
        "graph": f"qr_{mt}x{nt}",
        "instrumented_s": instr,
        "bare_s": bare,
        "ratio": instr / bare,
        "delta_us": (instr - bare) * 1e6,
    }


def main() -> None:
    out = {"qr": bench_qr(), "bh": bench_bh(),
           "obs_overhead": bench_obs_overhead()}
    q = out["qr"]
    for phase in ("build", "prepare", "lower", "total"):
        emit(f"sched_{phase}", q["array_s"][phase] * 1e6,
             f"legacy_us={q['legacy_s'][phase] * 1e6:.0f} "
             f"speedup={q['speedup'][phase]:.2f}x")
    emit("sched_lower_cached", q["array_s"]["lower_cached"] * 1e6,
         "plan-cache hit")
    emit("sched_tasks_per_sec", 0,
         f"array={q['tasks_per_sec']['array']:.0f} "
         f"legacy={q['tasks_per_sec']['legacy']:.0f}")
    b = out["bh"]
    emit("sched_bh_total", b["array_s"]["total"] * 1e6,
         f"tasks={b['tasks']} rounds={b['rounds']}")
    o = out["obs_overhead"]
    emit("sched_obs_overhead", o["delta_us"],
         f"ratio={o['ratio']:.3f} (tracing disabled, gate<=1.03)")
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sched.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    emit("sched_json", 0, str(path))


if __name__ == "__main__":
    main()
