"""Engine dispatch benchmark: per-round host dispatch vs the fused
device-resident engine (DESIGN.md §Engine), for all three task families
(QR, Barnes-Hut, pipeline F/B/U).  Writes ``BENCH_engine.json`` at the
repo root.

Three figures of merit per family:

* **host dispatches per plan** — the per-round BatchSpec path issues one
  host call per batched group and one per ``run_one`` task
  (``count_host_dispatches``); the engine issues exactly one jitted call
  for the whole plan.  This is the paper's Fig-13 overhead argument moved
  to the dispatch layer: scheduler *and* dispatch off the critical path.
* **walk rows** — the ragged CSR table walks exactly ``items`` descriptor
  rows; the padded slab layout it replaced walked ``rounds × max_width``
  (``walk_reduction`` is the ratio, the pad work eliminated; CI asserts
  ``pad_fraction == 0`` and per-family reduction floors).
* **execute wall time** — steady-state, graph/plan/lowering excluded,
  first calls excluded as compile.  For QR the per-round host path is
  timed against the engine; for every family the engine itself is timed
  both ways — per-round launches inside one jitted dispatch
  (``engine_looped``) vs one whole-plan megakernel launch
  (``engine_fused``) — the ROADMAP round-boundary-donation question
  measured: CI keeps fused ≤ looped.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

import jax.random

from repro import engine
from repro.apps import barneshut as bh
from repro.apps import qr
from repro.core import lower
from repro.pipeline import lower_pipeline_plan
from repro.pipeline.exec import (_PipeRunner, _engine_family, _engine_hooks,
                                 dense_stage, mse_loss,
                                 pipelined_value_and_grad_plan)

from .common import FULL, SMOKE, emit

REPEAT = 3 if SMOKE else 5


def _best(setup, timed, repeat=REPEAT):
    best, out = float("inf"), None
    for _ in range(repeat):
        st = setup()
        t0 = time.perf_counter()
        out = timed(st)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _walk_stats(tables: "engine.TaskTable") -> dict:
    stats = dict(tables.stats)
    stats["walk_reduction"] = stats["padded_rows"] / max(stats["items"], 1)
    return stats


def _time_engine_walks(tables, round_fn, statics, make_buffers,
                       repeat=max(REPEAT, 5)) -> dict:
    """Steady-state engine execute times, per-round-looped vs whole-plan
    fused, fresh buffers per repeat, first call per mode excluded as
    compile.  Best-of-5 even at smoke sizes: CI floors compare the two
    modes against each other, so jitter matters more than wall time."""
    out = {}
    for name, fuse in (("engine_looped", False), ("engine_fused", True)):
        engine.execute_plan(tables, round_fn, statics, make_buffers(),
                            fuse_rounds=fuse)                    # warmup

        def run(bufs, fuse=fuse):
            res = engine.execute_plan(tables, round_fn, statics, bufs,
                                      fuse_rounds=fuse)
            jax.block_until_ready(res)
            return res
        out[name], _ = _best(make_buffers, run, repeat=repeat)
    return out


def bench_qr():
    mt = nt = 16 if FULL else (6 if SMOKE else 8)
    b = 32
    n = mt * b
    a = jnp.asarray(np.random.default_rng(0).standard_normal((n, n)),
                    jnp.float32)
    tiles, _, _ = qr._split_tiles(a, b)
    sched, _ = qr.make_qr_graph(mt, nt, nr_queues=4)
    plan = lower(sched, 4)
    registry = qr._TileState(dict(tiles), "pallas").batch_registry()
    host_dispatches = engine.count_host_dispatches(plan, sched, registry)

    # per-round host path: fresh tile state per repeat, execute timed
    # (block on the tile dict so both sides measure completed execution)
    def setup_rounds():
        return qr._TileState(dict(tiles), "pallas")

    def run_rounds(st):
        plan.execute(sched, st.batch_registry())
        jax.block_until_ready(st.tiles)
        return st
    t_rounds, _ = _best(setup_rounds, run_rounds)

    # engine: tables lowered once; fresh (donatable) buffers per repeat
    state = qr._TileState(dict(tiles), "pallas")
    tables = engine.lower_tables(
        plan, sched, state.batch_registry(),
        arg_width=engine.QR_ARG_WIDTH, row_access=engine.qr_row_access)
    stack0 = jnp.stack([tiles[i, j] for j in range(nt) for i in range(mt)])
    walks = _time_engine_walks(
        tables, engine.qr_round_fn(), (),
        lambda: (stack0 + 0.0, jnp.zeros_like(stack0)))

    tasks = sched.nr_tasks
    t_engine = walks["engine_looped"]
    return {
        "graph": f"qr_{mt}x{nt}",
        "tasks": tasks,
        "rounds": plan.nr_rounds,
        "table": _walk_stats(tables),
        "host_dispatches": {
            "per_round": host_dispatches,
            "engine": engine.ENGINE_DISPATCHES_PER_PLAN,
        },
        "dispatch_reduction": host_dispatches
        / engine.ENGINE_DISPATCHES_PER_PLAN,
        "execute_s": {"per_round": t_rounds, "engine": t_engine, **walks},
        "speedup": t_rounds / t_engine,
        "tasks_per_sec": {"per_round": tasks / t_rounds,
                          "engine": tasks / t_engine},
    }


def bench_bh():
    n = 20000 if FULL else (2000 if SMOKE else 4000)
    rng = np.random.default_rng(11)
    x, m = rng.random((n, 3)), rng.random(n) + 0.5
    tree = bh.Octree(x, m, n_max=64)
    g = bh.build_graph(tree, n_task=256, nr_queues=4)
    st = bh.BHState(g, backend="pallas")
    plan = lower(g.sched, 4)
    registry = st.batch_registry()
    host_dispatches = engine.count_host_dispatches(plan, g.sched, registry)
    tables = engine.lower_tables(plan, g.sched, registry,
                                 arg_width=engine.BH_ARG_WIDTH,
                                 row_access=engine.bh_row_access)
    hooks = st.engine_hooks()
    statics = hooks.statics()
    walks = _time_engine_walks(tables, hooks.round_fn, statics,
                               hooks.buffers)
    return {
        "graph": f"bh_{n}",
        "tasks": g.sched.nr_tasks,
        "rounds": plan.nr_rounds,
        "table": _walk_stats(tables),
        "host_dispatches": {
            "per_round": host_dispatches,
            "engine": engine.ENGINE_DISPATCHES_PER_PLAN,
        },
        "dispatch_reduction": host_dispatches
        / engine.ENGINE_DISPATCHES_PER_PLAN,
        "execute_s": {"engine": walks["engine_looped"], **walks},
    }


def bench_pipeline():
    """Pipeline F/B/U family (ISSUE 4): host dispatches of the per-task
    path vs the single-dispatch engine, plus end-to-end value-and-grad
    wall time on the canonical dense family."""
    S, M = (8, 64) if FULL else ((4, 16) if SMOKE else (4, 32))
    bt, dim = 4, 32
    key = jax.random.PRNGKey(0)
    params = [{"w": jax.random.normal(jax.random.fold_in(key, k),
                                      (dim, dim)) * 0.3,
               "b": jnp.zeros((dim,))} for k in range(S)]
    micro = [{"x": jax.random.normal(jax.random.fold_in(key, 100 + m),
                                     (bt, dim)),
              "y": jax.random.normal(jax.random.fold_in(key, 200 + m),
                                     (bt, dim))} for m in range(M)]
    runner = _PipeRunner([dense_stage] * S, mse_loss, params, micro)
    sched, _, plan = lower_pipeline_plan(S, M, per_stage_window=True)
    registry = runner.registry()
    host_dispatches = engine.count_host_dispatches(plan, sched, registry)
    tables = engine.lower_tables(plan, sched, registry,
                                 arg_width=engine.PIPE_ARG_WIDTH,
                                 row_access=engine.pipe_row_access)
    fam = _engine_family([dense_stage] * S, mse_loss, params, micro)
    hooks = _engine_hooks(params, micro, fam, {})
    statics = hooks.statics()
    walks = _time_engine_walks(tables, hooks.round_fn, statics,
                               hooks.buffers)

    def run_mode(mode):
        def timed(_):
            out = pipelined_value_and_grad_plan(
                [dense_stage] * S, mse_loss, params, micro, mode=mode)
            jax.block_until_ready(out)
            return out
        timed(None)                       # warmup (engine: compile)
        return _best(lambda: None, timed, repeat=3)[0]

    t_rounds = run_mode("rounds")
    t_engine = run_mode("engine")
    return {
        "graph": f"pipeline_S{S}_M{M}",
        "tasks": sched.nr_tasks,
        "rounds": plan.nr_rounds,
        "table": _walk_stats(tables),
        "host_dispatches": {
            "per_round": host_dispatches,
            "engine": engine.ENGINE_DISPATCHES_PER_PLAN,
        },
        "dispatch_reduction": host_dispatches
        / engine.ENGINE_DISPATCHES_PER_PLAN,
        "execute_s": {"per_round": t_rounds, "engine": t_engine, **walks},
    }


def main() -> None:
    out = {"qr": bench_qr(), "bh": bench_bh(), "pipeline": bench_pipeline()}
    q = out["qr"]
    emit("engine_qr_per_round_us", q["execute_s"]["per_round"] * 1e6,
         f"dispatches={q['host_dispatches']['per_round']}")
    emit("engine_qr_engine_us", q["execute_s"]["engine"] * 1e6,
         f"dispatches={q['host_dispatches']['engine']} "
         f"speedup={q['speedup']:.2f}x "
         f"dispatch_reduction={q['dispatch_reduction']:.0f}x")
    emit("engine_qr_tasks_per_sec", 0,
         f"engine={q['tasks_per_sec']['engine']:.0f} "
         f"per_round={q['tasks_per_sec']['per_round']:.0f}")
    b = out["bh"]
    emit("engine_bh_engine_us", b["execute_s"]["engine"] * 1e6,
         f"tasks={b['tasks']} rounds={b['rounds']} "
         f"dispatch_reduction={b['dispatch_reduction']:.0f}x")
    p = out["pipeline"]
    emit("engine_pipe_engine_us", p["execute_s"]["engine"] * 1e6,
         f"tasks={p['tasks']} rounds={p['rounds']} "
         f"dispatches={p['host_dispatches']['per_round']} "
         f"dispatch_reduction={p['dispatch_reduction']:.0f}x")
    for fam in ("qr", "bh", "pipeline"):
        f = out[fam]
        emit(f"engine_{fam}_walk", f["table"]["items"],
             f"pad_fraction={f['table']['pad_fraction']:.2f} "
             f"walk_reduction={f['table']['walk_reduction']:.2f}x "
             f"phases={f['table']['phases']} "
             f"fused_us={f['execute_s']['engine_fused'] * 1e6:.0f} "
             f"looped_us={f['execute_s']['engine_looped'] * 1e6:.0f}")
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_engine.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    emit("engine_json", 0, str(path))


if __name__ == "__main__":
    main()
