"""Engine dispatch benchmark: per-round host dispatch vs the fused
device-resident engine (DESIGN.md §Engine), for all three task families
(QR, Barnes-Hut, pipeline F/B/U).  Writes ``BENCH_engine.json`` at the
repo root.

Two figures of merit per family:

* **host dispatches per plan** — the per-round BatchSpec path issues one
  host call per batched group and one per ``run_one`` task
  (``count_host_dispatches``); the engine issues exactly one jitted call
  for the whole plan.  This is the paper's Fig-13 overhead argument moved
  to the dispatch layer: scheduler *and* dispatch off the critical path.
* **execute wall time** (QR) — steady-state, graph/plan/lowering excluded
  from both sides, first engine call excluded as compile: the per-round
  path re-runs ``plan.execute`` against a fresh tile state; the engine
  re-runs the single fused dispatch against fresh buffers.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

import jax.random

from repro import engine
from repro.apps import barneshut as bh
from repro.apps import qr
from repro.core import lower
from repro.pipeline import lower_pipeline_plan
from repro.pipeline.exec import (_PipeRunner, dense_stage, mse_loss,
                                 pipelined_value_and_grad_plan)

from .common import FULL, SMOKE, emit

REPEAT = 3 if SMOKE else 5


def _best(setup, timed, repeat=REPEAT):
    best, out = float("inf"), None
    for _ in range(repeat):
        st = setup()
        t0 = time.perf_counter()
        out = timed(st)
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_qr():
    mt = nt = 16 if FULL else (6 if SMOKE else 8)
    b = 32
    n = mt * b
    a = jnp.asarray(np.random.default_rng(0).standard_normal((n, n)),
                    jnp.float32)
    tiles, _, _ = qr._split_tiles(a, b)
    sched, _ = qr.make_qr_graph(mt, nt, nr_queues=4)
    plan = lower(sched, 4)
    registry = qr._TileState(dict(tiles), "pallas").batch_registry()
    host_dispatches = engine.count_host_dispatches(plan, sched, registry)

    # per-round host path: fresh tile state per repeat, execute timed
    # (block on the tile dict so both sides measure completed execution)
    def setup_rounds():
        return qr._TileState(dict(tiles), "pallas")

    def run_rounds(st):
        plan.execute(sched, st.batch_registry())
        jax.block_until_ready(st.tiles)
        return st
    t_rounds, _ = _best(setup_rounds, run_rounds)

    # engine: tables lowered once; fresh (donatable) buffers per repeat
    state = qr._TileState(dict(tiles), "pallas")
    tables = engine.lower_tables(
        plan, sched, state.batch_registry(),
        arg_width=engine.QR_ARG_WIDTH, pad_type=engine.QR_NOOP)
    stack0 = jnp.stack([tiles[i, j] for j in range(nt) for i in range(mt)])

    def setup_engine():
        return (stack0 + 0.0, jnp.zeros_like(stack0))
    fn = engine.qr_round_fn()
    engine.execute_plan(tables, fn, (), setup_engine())   # compile warmup

    def run_engine(bufs):
        out = engine.execute_plan(tables, fn, (), bufs)
        out[0].block_until_ready()
        return out
    t_engine, _ = _best(setup_engine, run_engine)

    tasks = sched.nr_tasks
    return {
        "graph": f"qr_{mt}x{nt}",
        "tasks": tasks,
        "rounds": plan.nr_rounds,
        "table": dict(tables.stats),
        "host_dispatches": {
            "per_round": host_dispatches,
            "engine": engine.ENGINE_DISPATCHES_PER_PLAN,
        },
        "dispatch_reduction": host_dispatches
        / engine.ENGINE_DISPATCHES_PER_PLAN,
        "execute_s": {"per_round": t_rounds, "engine": t_engine},
        "speedup": t_rounds / t_engine,
        "tasks_per_sec": {"per_round": tasks / t_rounds,
                          "engine": tasks / t_engine},
    }


def bench_bh():
    n = 20000 if FULL else (2000 if SMOKE else 4000)
    rng = np.random.default_rng(11)
    x, m = rng.random((n, 3)), rng.random(n) + 0.5
    tree = bh.Octree(x, m, n_max=64)
    g = bh.build_graph(tree, n_task=256, nr_queues=4)
    st = bh.BHState(g, backend="pallas")
    plan = lower(g.sched, 4)
    registry = st.batch_registry()
    host_dispatches = engine.count_host_dispatches(plan, g.sched, registry)
    tables = engine.lower_tables(plan, g.sched, registry,
                                 arg_width=engine.BH_ARG_WIDTH,
                                 pad_type=engine.BH_NOOP)

    def run_engine(state):
        state.run(mode="engine", nr_workers=4)
        return state
    bh.BHState(g, backend="pallas").run(mode="engine")     # compile warmup
    t_engine, _ = _best(lambda: bh.BHState(g, backend="pallas"), run_engine,
                        repeat=3)
    return {
        "graph": f"bh_{n}",
        "tasks": g.sched.nr_tasks,
        "rounds": plan.nr_rounds,
        "table": dict(tables.stats),
        "host_dispatches": {
            "per_round": host_dispatches,
            "engine": engine.ENGINE_DISPATCHES_PER_PLAN,
        },
        "dispatch_reduction": host_dispatches
        / engine.ENGINE_DISPATCHES_PER_PLAN,
        "execute_s": {"engine": t_engine},
    }


def bench_pipeline():
    """Pipeline F/B/U family (ISSUE 4): host dispatches of the per-task
    path vs the single-dispatch engine, plus end-to-end value-and-grad
    wall time on the canonical dense family."""
    S, M = (8, 64) if FULL else ((4, 16) if SMOKE else (4, 32))
    bt, dim = 4, 32
    key = jax.random.PRNGKey(0)
    params = [{"w": jax.random.normal(jax.random.fold_in(key, k),
                                      (dim, dim)) * 0.3,
               "b": jnp.zeros((dim,))} for k in range(S)]
    micro = [{"x": jax.random.normal(jax.random.fold_in(key, 100 + m),
                                     (bt, dim)),
              "y": jax.random.normal(jax.random.fold_in(key, 200 + m),
                                     (bt, dim))} for m in range(M)]
    runner = _PipeRunner([dense_stage] * S, mse_loss, params, micro)
    sched, _, plan = lower_pipeline_plan(S, M, per_stage_window=True)
    host_dispatches = engine.count_host_dispatches(plan, sched,
                                                   runner.registry())

    def run_mode(mode):
        def timed(_):
            out = pipelined_value_and_grad_plan(
                [dense_stage] * S, mse_loss, params, micro, mode=mode)
            jax.block_until_ready(out)
            return out
        timed(None)                       # warmup (engine: compile)
        return _best(lambda: None, timed, repeat=3)[0]

    t_rounds = run_mode("rounds")
    t_engine = run_mode("engine")
    return {
        "graph": f"pipeline_S{S}_M{M}",
        "tasks": sched.nr_tasks,
        "rounds": plan.nr_rounds,
        "host_dispatches": {
            "per_round": host_dispatches,
            "engine": engine.ENGINE_DISPATCHES_PER_PLAN,
        },
        "dispatch_reduction": host_dispatches
        / engine.ENGINE_DISPATCHES_PER_PLAN,
        "execute_s": {"per_round": t_rounds, "engine": t_engine},
    }


def main() -> None:
    out = {"qr": bench_qr(), "bh": bench_bh(), "pipeline": bench_pipeline()}
    q = out["qr"]
    emit("engine_qr_per_round_us", q["execute_s"]["per_round"] * 1e6,
         f"dispatches={q['host_dispatches']['per_round']}")
    emit("engine_qr_engine_us", q["execute_s"]["engine"] * 1e6,
         f"dispatches={q['host_dispatches']['engine']} "
         f"speedup={q['speedup']:.2f}x "
         f"dispatch_reduction={q['dispatch_reduction']:.0f}x")
    emit("engine_qr_tasks_per_sec", 0,
         f"engine={q['tasks_per_sec']['engine']:.0f} "
         f"per_round={q['tasks_per_sec']['per_round']:.0f}")
    b = out["bh"]
    emit("engine_bh_engine_us", b["execute_s"]["engine"] * 1e6,
         f"tasks={b['tasks']} rounds={b['rounds']} "
         f"dispatch_reduction={b['dispatch_reduction']:.0f}x")
    p = out["pipeline"]
    emit("engine_pipe_engine_us", p["execute_s"]["engine"] * 1e6,
         f"tasks={p['tasks']} rounds={p['rounds']} "
         f"dispatches={p['host_dispatches']['per_round']} "
         f"dispatch_reduction={p['dispatch_reduction']:.0f}x")
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_engine.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    emit("engine_json", 0, str(path))


if __name__ == "__main__":
    main()
