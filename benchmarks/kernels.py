"""Per-kernel micro-benchmarks (CPU interpret mode: numbers are structural
sanity / regression tracking, NOT TPU performance — the TPU roofline lives
in benchmarks/roofline.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.nbody import ops as nbody_ops
from repro.kernels.qr_tile import ops as qr_ops

from .common import emit, time_us


def main() -> None:
    rng = np.random.default_rng(0)
    for b in (32, 64):
        a = jnp.asarray(rng.standard_normal((b, b)), jnp.float32)
        rv, tau, t = qr_ops.geqrf(a)
        jax.block_until_ready(rv)
        us = time_us(lambda: jax.block_until_ready(qr_ops.geqrf(a)))
        emit(f"kernel_geqrf_{b}", us, f"flops~{4 / 3 * b**3:.0f}")
        c = jnp.asarray(rng.standard_normal((b, b)), jnp.float32)
        us = time_us(
            lambda: jax.block_until_ready(qr_ops.apply_qt(rv, t, c)))
        emit(f"kernel_apply_qt_{b}", us, f"flops~{3 * b**3:.0f}")
    for n in (512, 2048):
        x = jnp.asarray(rng.standard_normal((3, n)), jnp.float32)
        m = jnp.asarray(rng.random(n), jnp.float32)
        jax.block_until_ready(nbody_ops.acc_self(x, m))
        us = time_us(lambda: jax.block_until_ready(nbody_ops.acc_self(x, m)))
        emit(f"kernel_nbody_self_{n}", us, f"interactions={n * (n - 1)}")


if __name__ == "__main__":
    main()
