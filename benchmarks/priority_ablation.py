"""Paper Fig 9 claim: critical-path weights schedule DGEQRF tasks as soon
as available, preventing end-of-computation bottlenecks (vs OmpSs).

Ablation on the QR graph: (a) critical-path weights (the paper),
(b) flat weights (FIFO-ish greedy), (c) cost-only weights (no lookahead).
Plus DGEQRF start-time statistics (the Fig 9 visual, quantified)."""

from __future__ import annotations

from repro.apps import qr
from repro.core import simulate

from .common import emit


def run(mt: int, n: int, mode: str):
    s, _ = qr.make_qr_graph(mt, mt, nr_queues=n)
    s.prepare()
    if mode == "flat":
        for t in s.tasks:
            t.weight = 1.0
    elif mode == "cost":
        for t in s.tasks:
            t.weight = t.cost
    s._prepared = True
    return s, simulate(s, n)


def main() -> None:
    mt, n = 32, 64
    base = None
    for mode in ("critical_path", "flat", "cost"):
        s, r = run(mt, n, mode)
        if base is None:
            base = r.makespan
        # mean normalized start time of DGEQRF(k) relative to level k
        geqrf = [(s.tasks[e.tid].data[2], e.t0) for e in r.timeline
                 if s.tasks[e.tid].type == qr.T_GEQRF]
        lateness = sum(t0 for _, t0 in geqrf) / len(geqrf) / r.makespan
        emit(f"qr_priority_{mode}", 0,
             f"makespan={r.makespan:.0f} vs_cp={r.makespan / base:.3f}x "
             f"geqrf_mean_start_frac={lateness:.3f}")


if __name__ == "__main__":
    main()
