"""Paper Fig 11 + Fig 13 + §4.2 counts: Barnes-Hut scaling, per-task-type
cost accounting, and scheduler overhead fraction.

Default 100k particles (REPRO_FULL=1 → the paper's 1M / n_max=100 /
n_task=5000, which reproduces the 512 self / 5068 pair / 32768 pc counts
on a uniform distribution).  Paper: 75% efficiency at 64 cores, 90% at 32
(the >32 falloff is hardware L2 sharing, excluded here by construction);
scheduler overhead < 1%."""

from __future__ import annotations

import time

import numpy as np

from repro.apps import barneshut as bh
from repro.core import simulate

from .common import FULL, SMOKE, emit


def main() -> None:
    n = 1_000_000 if FULL else (20_000 if SMOKE else 100_000)
    # the paper's granularity gives ≥8 stop cells per worker at 1M/5000;
    # keep the same cells-per-worker ratio at the reduced default size
    n_max, n_task = 100, (5000 if FULL else (500 if SMOKE else 1000))
    rng = np.random.default_rng(42)
    x = rng.random((n, 3))
    m = rng.random(n) + 0.5

    t0 = time.perf_counter()
    tree = bh.Octree(x, m, n_max=n_max)
    emit("bh_tree_build", (time.perf_counter() - t0) * 1e6,
         f"cells={len(tree.cells)}")

    t0 = time.perf_counter()
    g = bh.build_graph(tree, n_task=n_task)
    emit("bh_graph_build", (time.perf_counter() - t0) * 1e6, "")
    c = g.counts
    paper = ("paper(1M): self=512 pair=5068 pc=32768 locks=43416 "
             "res=37449")
    emit("bh_tasks", 0,
         f"self={c['self']} pair={c['pair_pp']} pc={c['pair_pc']} "
         f"com={c['com']} locks={c['locks']} res={c['resources']}; {paper}")

    def make(nq):
        t2 = bh.Octree(x, m, n_max=n_max)
        return bh.build_graph(t2, n_task=n_task, nr_queues=nq).sched

    r1 = simulate(make(1), 1)
    t1 = r1.makespan
    for nq in (1, 8, 32) if SMOKE else (1, 2, 4, 8, 16, 32, 64):
        t0 = time.perf_counter()
        r = simulate(make(nq), nq, overhead=t1 * 1e-7)
        sim_us = (time.perf_counter() - t0) * 1e6
        eff = t1 / (nq * r.makespan)
        # per-type accumulated cost (Fig 13)
        per = {bh.TASK_NAMES[k]: v for k, v in r.per_type_cost.items()}
        ov = r.overhead_time / (nq * r.makespan)
        emit(f"bh_scaling_{nq:02d}", sim_us,
             f"efficiency={eff:.3f} overhead_frac={ov:.4f} "
             f"self={per.get('self', 0):.3g} pair={per.get('pair_pp', 0):.3g} "
             f"pc={per.get('pair_pc', 0):.3g}")


if __name__ == "__main__":
    main()
