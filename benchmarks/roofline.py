"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
cell from the dry-run artifacts.

  compute term    = HLO_FLOPs / (chips × 197 TF/s bf16)     [per-chip form]
  memory term     = HLO_bytes / (chips × 819 GB/s HBM)
  collective term = collective_operand_bytes / (chips × 50 GB/s link)

The dry-run records PER-CHIP HLO numbers (the compiled module is the
post-SPMD per-device program), so each term is per-chip value / per-chip
rate.  FLOP/collective numbers use the depth-extrapolated values (scan
bodies are counted once by HloCostAnalysis; launch/dryrun.py probes two
unrolled depths and extrapolates — verified in tests/test_dryrun_small.py).

MODEL_FLOPS = 6·N_active·D (training) or 2·N_active·D (inference); the
ratio MODEL_FLOPS / HLO_FLOPs exposes remat/replication waste.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip (v5e)
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_cells(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    cells = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    ex = rec.get("extrapolated") or {}
    full = rec["full"]
    flops = ex.get("flops_per_device", full["flops_per_device"])
    bytes_acc = ex.get("bytes_accessed_per_device",
                       full["bytes_accessed_per_device"])
    coll = ex.get("collective_operand_bytes_per_device",
                  full["collective_operand_bytes_per_device"])
    t_c = flops / PEAK_FLOPS
    t_m = bytes_acc / HBM_BW
    t_x = coll / LINK_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    n_tokens = (rec["global_batch"] * rec["seq_len"]
                if rec["kind"] in ("train", "prefill")
                else rec["global_batch"])
    model_flops = (6.0 if rec["kind"] == "train" else 2.0) \
        * rec["active_params"] * n_tokens
    mf_per_chip = model_flops / rec["chips"]
    t_total = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": rec["chips"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": mf_per_chip / max(flops, 1.0),
        "roofline_frac": (mf_per_chip / PEAK_FLOPS) / max(t_total, 1e-30),
        "peak_gib": full["memory"]["peak_bytes"] / 2**30,
        "arg_gib": full["memory"]["argument_bytes"] / 2**30,
    }


MOVE_HINTS = {
    "compute": ("cut replicated per-chip compute (activation sharding "
                "constraints / drop remat on cheap layers)"),
    "memory": ("larger fused blocks or bf16 intermediates to cut HBM "
               "traffic; kernel fusion of the dominant elementwise chains"),
    "collective": ("reshard to cut all-gather volume (FSDP prefetch, "
                   "overlap collectives with compute, int8 DP traffic)"),
}


def table(cells: List[Dict], mesh: str = "single") -> str:
    rows = []
    head = ("| arch | shape | compute s | memory s | collective s | "
            "dominant | useful/HLO | roofline frac | state GiB/chip |")
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for rec in cells:
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped | — | — | — |")
            continue
        t = roofline_terms(rec)
        if t is None:
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"ERROR | — | — | — |")
            continue
        rows.append(
            f"| {t['arch']} | {t['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant']} | {t['useful_ratio']:.2f} | "
            f"{t['roofline_frac']:.3f} | {t['arg_gib']:.1f} |")
    return "\n".join(rows)


def main() -> None:
    from .common import emit
    cells = load_cells()
    if not cells:
        emit("roofline", 0, "no dryrun artifacts yet (run launch/dryrun.py)")
        return
    n_ok = sum(1 for c in cells if c.get("status") == "ok")
    n_skip = sum(1 for c in cells if c.get("status") == "skipped")
    emit("roofline_cells", 0, f"ok={n_ok} skipped={n_skip} "
                              f"total={len(cells)}")
    worst = None
    for rec in cells:
        t = roofline_terms(rec)
        if t is None:
            continue
        emit(f"roofline_{rec['mesh']}_{rec['arch']}_{rec['shape']}", 0,
             f"compute={t['compute_s']:.3e}s memory={t['memory_s']:.3e}s "
             f"collective={t['collective_s']:.3e}s dom={t['dominant']} "
             f"useful={t['useful_ratio']:.2f} frac={t['roofline_frac']:.3f}")
        if rec["mesh"] == "single" and (worst is None
                                        or t["roofline_frac"] < worst[0]):
            worst = (t["roofline_frac"], rec["arch"], rec["shape"])
    if worst:
        emit("roofline_worst_cell", 0,
             f"{worst[1]}/{worst[2]} frac={worst[0]:.4f}")


if __name__ == "__main__":
    main()
