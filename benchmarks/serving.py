"""Continuous batching vs the seed's static-batch serving loop.

The static loop (``launch/serve.py``'s original shape) prefills a fixed
batch and decodes every member until the *slowest* one finishes; with a
ragged distribution of generation budgets most decode positions in most
steps are wasted work.  The continuous service retires a request the step
its budget is met and admits the next queued request into the freed slot,
so decode batches stay full of useful work.  Both paths run the same
model, same requests, same greedy decoding; the figure of merit is
sustained useful tokens/sec after warmup (the services stay persistent —
all entry points compiled — and the second replay is timed).

A second figure of merit is the decode *round function* itself: the
paged-attention rework bounds each tick's attention/gather work by the
pages a slot actually occupies (in-kernel page walk on compiled backends,
window-bounded gather elsewhere) instead of the full ``max_seq`` window.
``decode_microbench`` times the service's selected round function against
the full-window ``gather`` oracle at fixed occupancy — same buffers, same
descriptor, jitted and warmed — and reports ``decode_speedup``.

Writes ``BENCH_serve.json`` at the repo root; CI floors
``speedup >= 1.05`` and ``decode_speedup >= 1.5`` at smoke size.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm, serving
from repro.serve import GenerateService
from repro.serve.traffic import open_loop_trace, replay
from repro.trainer.steps import make_serve_step

from .common import FULL, SMOKE, emit

ARCH = "qwen3-1.7b"

if SMOKE:
    N_REQ, MAX_BATCH, PLEN = 12, 4, 8
    NEW_CHOICES = (1, 2, 4, 32)
elif FULL:
    N_REQ, MAX_BATCH, PLEN = 48, 8, 16
    NEW_CHOICES = (4, 8, 16, 32)
else:
    N_REQ, MAX_BATCH, PLEN = 24, 4, 8
    NEW_CHOICES = (2, 4, 8, 32)

PAGE = 8
MAX_SEQ = -(-(PLEN + max(NEW_CHOICES) - 1) // PAGE) * PAGE

# decode microbenchmark: long-context capacity so the full-window oracle
# pays for the positions the slots don't occupy (pos ~ 11 of 512)
MICRO_SEQ = 512
MICRO_POS = 11
MICRO_ITERS = 20 if SMOKE else 50


def make_static_prefill(cfg):
    """Jitted batch prefill + cache pad for the static baseline (so the
    comparison isolates the scheduling discipline, not compilation)."""

    @jax.jit
    def fn(params, tokens):
        logits, cache, pos = serving.prefill(params, cfg, tokens)
        if cfg.family != "ssm":
            pad = [(0, 0)] * cache[next(iter(cache))].ndim
            pad[2] = (0, MAX_SEQ - PLEN)
            cache = {k: jnp.pad(v, pad) for k, v in cache.items()}
        return jnp.argmax(logits, -1)[:, None], cache, pos

    return fn


def static_batch_run(params, cfg, static_prefill, serve_step, trace):
    """The seed loop: waves of MAX_BATCH, each wave prefilled together and
    decoded until its slowest member finishes."""
    out_tokens = 0
    for w0 in range(0, len(trace), MAX_BATCH):
        wave = trace[w0:w0 + MAX_BATCH]
        tokens = jnp.asarray(np.stack([r.prompt for r in wave]))
        tok, cache, pos = static_prefill(params, tokens)
        for _ in range(max(r.max_new_tokens for r in wave) - 1):
            logits, cache = serve_step(params, cache, tok, pos)
            tok = jnp.argmax(logits, -1)[:, None]
            pos = pos + 1
        jax.block_until_ready(tok)
        out_tokens += sum(r.max_new_tokens for r in wave)  # useful only
    return out_tokens


def _decode_round_setup(params, cfg, prompts, decode_path, guard=True):
    """Build one service's jitted decode round function at fixed
    occupancy, warmed: admit a full batch, pin every slot to
    ``MICRO_POS``, freeze one descriptor/buffer set.  Returns a zero-arg
    timed call plus the resolved path — scheduling and host-sync overhead
    excluded, decode math isolated."""
    svc = GenerateService(params, cfg, max_batch=MAX_BATCH,
                          max_seq=MICRO_SEQ, page_size=PAGE,
                          decode_path=decode_path, guard=guard)
    for p in prompts:
        svc.submit(p, 2)
    svc._admit()
    svc._pos = jnp.full((MAX_BATCH,), MICRO_POS, jnp.int32)
    for req in svc._active.values():
        req.pos = MICRO_POS
    desc = jnp.asarray([[1, s, MICRO_POS] for s in sorted(svc._active)],
                       jnp.int32)
    fn = jax.jit(svc.hooks.round_fn)
    statics, bufs = svc._statics(), svc._buffers()
    jax.block_until_ready(fn(desc, None, statics, bufs))    # compile

    def call():
        return fn(desc, None, statics, bufs)

    return call, svc.decode_path


def _time_call(call, iters):
    t0 = time.perf_counter()
    for _ in range(iters):
        out = call()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _decode_round_time(params, cfg, prompts, decode_path, guard=True):
    call, path = _decode_round_setup(params, cfg, prompts, decode_path,
                                     guard)
    return _time_call(call, MICRO_ITERS), path


def decode_microbench(params, cfg):
    """Selected decode path (auto: kernel where compiled, bounded
    elsewhere) vs the full-window gather oracle."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=PLEN, dtype=np.int32)
               for _ in range(MAX_BATCH)]
    t_fast, path = _decode_round_time(params, cfg, prompts, "auto")
    t_slow, _ = _decode_round_time(params, cfg, prompts, "gather")
    return {
        "path": path,
        "batch": MAX_BATCH,
        "pos": MICRO_POS,
        "page_size": PAGE,
        "max_seq": MICRO_SEQ,
        "pages_walked": MICRO_POS // PAGE + 1,
        "pages_full_window": MICRO_SEQ // PAGE,
        "round_ms": t_fast * 1e3,
        "gather_round_ms": t_slow * 1e3,
        "decode_speedup": t_slow / t_fast,
    }


def guard_microbench(params, cfg):
    """Fault-free cost of the decode guard (post-round finiteness check +
    per-slot flag writeback, DESIGN.md §Robustness) on the selected
    path: same round function with and without the guard compiled in.
    CI gates the overhead at <= 5% (or a small absolute floor — at smoke
    size a round is sub-millisecond and the ratio is noise-dominated)."""
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, size=PLEN, dtype=np.int32)
               for _ in range(MAX_BATCH)]
    on, path = _decode_round_setup(params, cfg, prompts, "auto", guard=True)
    off, _ = _decode_round_setup(params, cfg, prompts, "auto", guard=False)
    # interleave timing blocks and keep each variant's best: transient
    # machine load hits both variants, not whichever ran second
    ts_on, ts_off = [], []
    for _ in range(5):
        ts_on.append(_time_call(on, MICRO_ITERS))
        ts_off.append(_time_call(off, MICRO_ITERS))
    t_on, t_off = min(ts_on), min(ts_off)
    return {
        "path": path,
        "round_ms_guarded": t_on * 1e3,
        "round_ms_unguarded": t_off * 1e3,
        "overhead_ratio": t_on / t_off,
        "overhead_us": (t_on - t_off) * 1e6,
    }


def main() -> None:
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    trace = open_loop_trace(N_REQ, mean_interarrival=0.0,
                            prompt_lens=(PLEN,), new_token_lens=NEW_CHOICES,
                            vocab_size=cfg.vocab, seed=7)
    useful = sum(r.max_new_tokens for r in trace)
    waves = [trace[i:i + MAX_BATCH] for i in range(0, len(trace), MAX_BATCH)]
    static_steps = sum(max(r.max_new_tokens for r in w) for w in waves)

    # static baseline: jit once, warm on the first replay, time the second
    serve_step = jax.jit(make_serve_step(cfg))
    static_prefill = make_static_prefill(cfg)
    static_batch_run(params, cfg, static_prefill, serve_step, trace)
    t0 = time.perf_counter()
    static_batch_run(params, cfg, static_prefill, serve_step, trace)
    t_static = time.perf_counter() - t0

    # continuous service: persistent instance, every entry point compiled
    # by the warmup replay, second replay timed
    svc = GenerateService(params, cfg, max_batch=MAX_BATCH,
                          max_seq=MAX_SEQ, page_size=PAGE)
    replay(svc, trace)
    warm_stats = dict(svc.stats)
    t0 = time.perf_counter()
    handles = replay(svc, trace)
    t_cont = time.perf_counter() - t0
    assert all(h.done and len(h.generated) == r.max_new_tokens
               for h, r in zip(handles, sorted(trace,
                                               key=lambda r: r.arrival_step)))

    micro = decode_microbench(params, cfg)
    guard = guard_microbench(params, cfg)

    cont_steps = svc.stats["steps"] - warm_stats["steps"]
    out = {
        "arch": ARCH,
        "workload": {"n_requests": N_REQ, "max_batch": MAX_BATCH,
                     "prompt_len": PLEN, "new_token_choices": NEW_CHOICES,
                     "useful_tokens": useful},
        "static": {"wall_s": t_static, "tok_s": useful / t_static,
                   "decode_steps": static_steps,
                   "decode_items": static_steps * MAX_BATCH},
        "continuous": {"wall_s": t_cont, "tok_s": useful / t_cont,
                       "decode_steps": cont_steps,
                       "decode_items": svc.stats["decode_items"]
                       - warm_stats["decode_items"],
                       "entry_points": svc.compiled_entry_points()},
        "speedup": t_static / t_cont,       # continuous runs guard-on
        "decode": micro,
        "decode_speedup": micro["decode_speedup"],
        "guard": guard,
        "guard_overhead_ratio": guard["overhead_ratio"],
    }
    emit("serve_static_tok_s", t_static / useful * 1e6,
         f"tok_s={out['static']['tok_s']:.1f} steps={static_steps}")
    emit("serve_continuous_tok_s", t_cont / useful * 1e6,
         f"tok_s={out['continuous']['tok_s']:.1f} steps={cont_steps} "
         f"speedup={out['speedup']:.2f}x")
    emit("serve_decode_round_ms", micro["round_ms"],
         f"{micro['path']} {micro['round_ms']:.2f}ms vs gather "
         f"{micro['gather_round_ms']:.2f}ms = "
         f"{micro['decode_speedup']:.2f}x at pos={micro['pos']}")
    emit("serve_guard_overhead", guard["overhead_ratio"],
         f"guarded {guard['round_ms_guarded']:.2f}ms vs unguarded "
         f"{guard['round_ms_unguarded']:.2f}ms = "
         f"{guard['overhead_ratio']:.3f}x ({guard['overhead_us']:+.0f}us)")
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    emit("serve_json", 0, str(path))


if __name__ == "__main__":
    main()
