"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows; ``derived``
carries the figure-of-merit for that experiment (efficiency, ratio, ...).
Set REPRO_FULL=1 for paper-size problems (1M particles / 2048² matrices);
set REPRO_SMOKE=1 for CI-sized problems that exercise every perf path in
seconds (the workflow runs these so hot-path regressions fail fast).
"""

from __future__ import annotations

import os
import time
from typing import Callable

FULL = os.environ.get("REPRO_FULL", "0") == "1"
SMOKE = os.environ.get("REPRO_SMOKE", "0") == "1" and not FULL


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_us(fn: Callable, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6
