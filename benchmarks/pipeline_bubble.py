"""QuickSched→pipeline synthesis (beyond-paper integration): bubble
fraction vs the analytic 1F1B bound, with and without the activation
throttle, across stage/microbatch counts and fwd:bwd cost ratios."""

from __future__ import annotations

from repro.pipeline import (bubble_fraction, one_f_one_b_bubble,
                            synthesize_schedule)

from .common import emit, time_us


def main() -> None:
    for (S, M) in ((4, 16), (8, 32), (16, 64)):
        for bc in (1.0, 2.0):
            ps = synthesize_schedule(S, M, 1.0, bc, 0.0,
                                     per_stage_window=True)
            ps_free = synthesize_schedule(S, M, 1.0, bc, 0.0)
            emit(f"pipeline_S{S}_M{M}_bwd{bc:g}", 0,
                 f"bubble_1f1b_window={bubble_fraction(ps):.4f} "
                 f"bubble_unbounded={bubble_fraction(ps_free):.4f} "
                 f"analytic_1f1b={one_f_one_b_bubble(S, M):.4f}")
    us = time_us(lambda: synthesize_schedule(8, 32, per_stage_window=True))
    emit("pipeline_synthesis_cost", us, "S=8 M=32")


if __name__ == "__main__":
    main()
