"""The paper's core claim (§1): modelling order-free mutual exclusion as
*dependencies* (what dependency-only runtimes must do) artificially
serializes and hurts parallelism; *conflicts* don't.

On the real Barnes-Hut graph we replace every resource's conflicting task
set with a dependency chain in task-creation order (the StarPU/OmpSs
behaviour for accumulating writes) and compare simulated makespans."""

from __future__ import annotations

import numpy as np

from repro.apps import barneshut as bh
from repro.core import QSched, simulate

from .common import FULL, SMOKE, emit


def chainified(g: bh.BHGraph, nr_queues: int) -> QSched:
    """Clone the BH graph with conflicts → creation-order dep chains."""
    src = g.sched
    s = QSched(nr_queues=nr_queues, reown=False)
    for r in src.resources:
        s.addres(owner=r.owner, parent=r.parent)
    for t in src.tasks:
        s.addtask(t.type, data=t.data, cost=t.cost)
    for t in src.tasks:
        for j in t.unlocks:
            s.addunlock(t.tid, j)
    # chain EXACTLY the conflicting pairs (lock sets overlapping in the
    # ancestor/descendant sense), in creation order — what a dependency-
    # only runtime's inout regions would do.  Siblings do NOT chain.
    parents = [r.parent for r in src.resources]
    last_writer = {}                      # resource -> last locking task

    def ancestors(rid):
        out = []
        rid = parents[rid]
        while rid != -1:
            out.append(rid)
            rid = parents[rid]
        return out

    # descendants via child lists
    children = {}
    for rid, par in enumerate(parents):
        if par != -1:
            children.setdefault(par, []).append(rid)

    def subtree(rid):
        out, stack = [], [rid]
        while stack:
            k = stack.pop()
            out.append(k)
            stack.extend(children.get(k, []))
        return out

    for t in src.tasks:
        if not t.locks:
            continue
        blockers = set()
        for r in t.locks:
            for c in ancestors(r) + subtree(r):   # conflict closure of r
                if c in last_writer:
                    blockers.add(last_writer[c])
        for b in blockers:
            if b != t.tid:
                s.addunlock(b, t.tid)
        for r in t.locks:
            last_writer[r] = t.tid
    return s


def main() -> None:
    n = 300_000 if FULL else (15_000 if SMOKE else 60_000)
    rng = np.random.default_rng(7)
    x, m = rng.random((n, 3)), rng.random(n) + 0.5
    tree = bh.Octree(x, m, n_max=64)
    for nq in (32,) if SMOKE else (16, 32, 64):
        g = bh.build_graph(tree, n_task=1000, nr_queues=nq)
        r_conf = simulate(g.sched, nq)
        tree2 = bh.Octree(x, m, n_max=64)
        g2 = bh.build_graph(tree2, n_task=1000, nr_queues=nq)
        s_chain = chainified(g2, nq)
        r_chain = simulate(s_chain, nq)
        ratio = r_chain.makespan / r_conf.makespan
        emit(f"conflict_vs_deps_{nq:02d}", 0,
             f"makespan_conflicts={r_conf.makespan:.3g} "
             f"makespan_depchains={r_chain.makespan:.3g} "
             f"slowdown_from_chains={ratio:.3f}x")


if __name__ == "__main__":
    main()
