"""Benchmark harness — one module per paper table/figure + the roofline.
Prints ``name,us_per_call,derived`` CSV.  REPRO_FULL=1 for paper-size runs.

    PYTHONPATH=src python -m benchmarks.run [section ...]
"""

from __future__ import annotations

import sys
import traceback

SECTIONS = ("sched_overhead", "engine_dispatch", "qr_scaling", "bh_scaling",
            "priority_ablation", "conflict_ablation", "pipeline_bubble",
            "serving", "kernels", "roofline")


def main() -> None:
    want = sys.argv[1:] or list(SECTIONS)
    failed = []
    for name in want:
        print(f"# --- {name} ---", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        raise SystemExit(f"benchmark sections failed: {failed}")


if __name__ == "__main__":
    main()
