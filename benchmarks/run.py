"""Benchmark harness — one module per paper table/figure + the roofline.
Prints ``name,us_per_call,derived`` CSV.  REPRO_FULL=1 for paper-size runs.

    PYTHONPATH=src python -m benchmarks.run [--trace-dir DIR] [section ...]

``--trace-dir DIR`` records each section under a fresh tracer and writes
``DIR/<section>.json`` Chrome traces (open in https://ui.perfetto.dev).
Sections that gate overhead (``sched_overhead``) measure with tracing
*disabled*, so their trace holds only the records of the final reported
runs, not the timed loops.
"""

from __future__ import annotations

import pathlib
import sys
import traceback

SECTIONS = ("sched_overhead", "engine_dispatch", "qr_scaling", "bh_scaling",
            "priority_ablation", "conflict_ablation", "pipeline_bubble",
            "serving", "kernels", "roofline")

# sections whose measurement is invalid under an enabled tracer (they
# gate the *disabled* instrumentation cost) — never traced
UNTRACED = ("sched_overhead",)


def main() -> None:
    argv = sys.argv[1:]
    trace_dir = None
    if "--trace-dir" in argv:
        i = argv.index("--trace-dir")
        try:
            trace_dir = pathlib.Path(argv[i + 1])
        except IndexError:
            raise SystemExit("--trace-dir needs a directory argument")
        argv = argv[:i] + argv[i + 2:]
        trace_dir.mkdir(parents=True, exist_ok=True)
    want = argv or list(SECTIONS)
    failed = []
    for name in want:
        print(f"# --- {name} ---", flush=True)
        tracing = trace_dir is not None and name not in UNTRACED
        if tracing:
            from repro.obs import enable as obs_enable
            obs_enable()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        finally:
            if tracing:
                from repro.obs import disable as obs_disable
                from repro.obs import write_chrome_trace
                out = trace_dir / f"{name}.json"
                info = write_chrome_trace(out)
                obs_disable()
                print(f"# trace: {out} ({info['events']} events)",
                      flush=True)
    if failed:
        raise SystemExit(f"benchmark sections failed: {failed}")


if __name__ == "__main__":
    main()
