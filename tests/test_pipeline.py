"""QuickSched pipeline synthesis (paper technique → LM training feature):
schedule validity, 1F1B-equivalent bubble, numerical equivalence of the
pipelined gradient, and the priority ablation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QSched, simulate
from repro.pipeline import (build_pipeline_graph, bubble_fraction,
                            one_f_one_b_bubble, synthesize_schedule)
from repro.pipeline.exec import pipelined_value_and_grad


class TestSynthesis:
    @pytest.mark.parametrize("S,M", [(2, 4), (4, 8), (4, 16), (8, 32)])
    def test_bubble_at_most_1f1b(self, S, M):
        """Equal-cost fwd/bwd: the synthesized schedule must be at least as
        tight as the analytic 1F1B bubble."""
        ps = synthesize_schedule(S, M, fwd_cost=1.0, bwd_cost=1.0,
                                 upd_cost=0.0)
        measured = bubble_fraction(ps)
        analytic = one_f_one_b_bubble(S, M)
        assert measured <= analytic + 0.02, (measured, analytic)

    def test_schedule_valid_and_complete(self):
        sched, _ = build_pipeline_graph(4, 8)
        res = simulate(sched, 4)
        sched.validate_schedule(res.timeline)
        # every lane serialized: no overlapping intervals per stage
        ps = synthesize_schedule(4, 8)
        for lane in ps.lanes:
            for a, b in zip(lane, lane[1:]):
                assert b[3] >= a[4] - 1e-9

    @pytest.mark.parametrize("S,M,fc,bc", [(4, 16, 1.0, 1.0),
                                            (4, 16, 1.0, 2.0),
                                            (8, 32, 1.0, 2.0)])
    def test_one_f_one_b_emerges(self, S, M, fc, bc):
        """With the 1F1B stash profile (per-stage window W_k = S-k) the
        greedy critical-path schedule reproduces the 1F1B bubble exactly —
        1F1B EMERGES from weights + conflicts, it is not hard-coded."""
        ps = synthesize_schedule(S, M, fwd_cost=fc, bwd_cost=bc,
                                 upd_cost=0.0, per_stage_window=True)
        assert bubble_fraction(ps) <= one_f_one_b_bubble(S, M) + 1e-6
        # last stage strictly alternates F,B (window 1)
        order = [k for k, _ in ps.order_for_stage(S - 1) if k != "U"]
        assert all(a != b for a, b in zip(order, order[1:])), order

    def test_in_flight_bound_respected(self):
        """Peak activation stash per stage ≤ max_in_flight (the memory
        guarantee 1F1B exists for)."""
        S, M = 4, 16
        ps = synthesize_schedule(S, M, 1.0, 1.0, 0.0, per_stage_window=True)
        for k in range(S):
            live = 0
            peak = 0
            for kind, m in ps.order_for_stage(k):
                if kind == "F":
                    live += 1
                elif kind == "B":
                    live -= 1
                peak = max(peak, live)
            assert peak <= S - k, f"stage {k} stash {peak} > {S - k}"
        # without the throttle stage 0 stashes all M microbatches
        ps0 = synthesize_schedule(S, M, 1.0, 1.0, 0.0)
        live = peak = 0
        for kind, m in ps0.order_for_stage(0):
            live += 1 if kind == "F" else (-1 if kind == "B" else 0)
            peak = max(peak, live)
        assert peak == M

    def test_priority_matters_vs_fifo(self):
        """Ablation: zeroing the critical-path weights (cost=epsilon on
        forwards) degrades or equals the schedule — weights are doing work."""
        good = synthesize_schedule(6, 24)
        sched, _ = build_pipeline_graph(6, 24)
        for t in sched.tasks:
            t.weight = 0.0  # will be overwritten by prepare(); force flat
        sched.prepare()
        for t in sched.tasks:
            t.weight = 1.0
        res = simulate(sched, 6)
        sched.validate_schedule(res.timeline)
        assert good.makespan <= res.makespan + 1e-9

    def test_update_conflicts_with_accumulation(self):
        """U(s) locks the grad buffer: it must never overlap any B(s,·)."""
        sched, meta = build_pipeline_graph(3, 6)
        res = simulate(sched, 3)
        by_stage = {}
        for ev in res.timeline:
            data = sched.tasks[ev.tid].data
            by_stage.setdefault(data[1], []).append((data[0], ev.t0, ev.t1))
        for k, evs in by_stage.items():
            u = [e for e in evs if e[0] == "U"]
            bs = [e for e in evs if e[0] == "B"]
            assert len(u) == 1
            for _, bt0, bt1 in bs:
                assert u[0][1] >= bt1 - 1e-9 or u[0][2] <= bt0 + 1e-9


class TestNumericalEquivalence:
    def test_pipelined_grad_equals_monolithic(self):
        S, M = 4, 8
        key = jax.random.PRNGKey(0)
        dims = [16, 32, 32, 32, 8]
        params = []
        for k in range(S):
            kk = jax.random.fold_in(key, k)
            params.append({
                "w": jax.random.normal(kk, (dims[k], dims[k + 1])) * 0.3,
                "b": jnp.zeros((dims[k + 1],)),
            })

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        def loss_fn(y, mb):
            return jnp.mean((y - mb["y"]) ** 2)

        micro = []
        for m in range(M):
            km = jax.random.fold_in(key, 100 + m)
            micro.append({"x": jax.random.normal(km, (4, dims[0])),
                          "y": jax.random.normal(
                              jax.random.fold_in(km, 1), (4, dims[-1]))})

        ps = synthesize_schedule(S, M)
        loss_p, grads_p = pipelined_value_and_grad(
            [stage_fn] * S, loss_fn, params, micro, ps)

        def monolithic(params_list):
            total = 0.0
            for mb in micro:
                h = mb["x"]
                for p in params_list:
                    h = stage_fn(p, h)
                total = total + loss_fn(h, mb)
            return total / M

        loss_m, grads_m = jax.value_and_grad(monolithic)(params)
        assert float(jnp.abs(loss_p - loss_m)) < 1e-6
        for gp, gm in zip(grads_p, grads_m):
            for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gm)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)

    def test_grad_accumulation_order_irrelevant(self):
        """Two different synthesized schedules (different cost ratios →
        different B orders) give identical gradients — the conflict
        model's whole point."""
        S, M = 3, 6
        key = jax.random.PRNGKey(1)
        params = [{"w": jax.random.normal(jax.random.fold_in(key, k),
                                          (8, 8)) * 0.3} for k in range(S)]

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        def loss_fn(y, mb):
            return jnp.mean(y ** 2)

        micro = [{"x": jax.random.normal(jax.random.fold_in(key, 10 + m),
                                         (4, 8))} for m in range(M)]
        g1 = pipelined_value_and_grad([stage_fn] * S, loss_fn, params, micro,
                                      synthesize_schedule(S, M, 1.0, 2.0))[1]
        g2 = pipelined_value_and_grad([stage_fn] * S, loss_fn, params, micro,
                                      synthesize_schedule(S, M, 2.0, 1.0))[1]
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            # identical up to float summation order
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-8)
