"""Property tests for the serving block pool (hypothesis; skipped via
conftest ``collect_ignore`` when hypothesis is absent).

The pool's safety contract, driven with random alloc/free/admission
traces:

* page conservation — allocated + free == pool size after every op;
* ownership disjointness — no page is ever held by two live owners;
* every admission batch from a correct allocator lowers to a single
  conflict-free round whose write coloring is one phase;
* a *forged* double assignment (bypassing ``alloc``) forces the planner
  to split rounds and ``plan_admission`` refuses it — canonical
  relabelling never masks a real conflict.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.plan import color_phases, lower
from repro.serve.blockpool import AdmissionConflict, BlockPool


# one trace op: (kind, payload) — sizes resolved against pool state at
# replay time so traces stay valid regardless of interleaving
_OPS = st.lists(
    st.tuples(st.sampled_from(["alloc", "free", "admit"]),
              st.integers(min_value=1, max_value=5)),
    min_size=1, max_size=40)


def _replay(n_pages, ops):
    """Drive a pool through a trace, checking invariants after every op.
    Returns the pool and the live allocation map."""
    pool = BlockPool(n_pages, page_size=4)
    live = {}                       # owner -> pages
    next_owner = 0
    for kind, size in ops:
        if kind == "alloc":
            if pool.can_admit(size):
                live[next_owner] = pool.alloc(size, owner=next_owner)
                next_owner += 1
        elif kind == "free" and live:
            owner = sorted(live)[size % len(live)]
            pool.free(live.pop(owner))
        elif kind == "admit":
            batch = []
            while len(batch) < size and pool.can_admit(2):
                batch.append(pool.alloc(2, owner=next_owner))
                live[next_owner] = batch[-1]
                next_owner += 1
            if batch:
                sched, plan = pool.plan_admission(batch)
                assert plan.nr_rounds == 1
        pool.check_invariants()
        claimed = [p for pages in live.values() for p in pages]
        assert len(claimed) == len(set(claimed)), \
            "a page is held by two live owners"
        assert pool.allocated == len(claimed)
        for owner, pages in live.items():
            assert all(pool.owner_of(p) == owner for p in pages)
    return pool, live


@settings(max_examples=60, deadline=None)
@given(ops=_OPS, n_pages=st.integers(min_value=4, max_value=24))
def test_trace_preserves_invariants(ops, n_pages):
    _replay(n_pages, ops)


@settings(max_examples=60, deadline=None)
@given(sizes=st.lists(st.integers(min_value=1, max_value=4),
                      min_size=1, max_size=6))
def test_admission_is_one_conflict_free_round(sizes):
    """Disjoint allocations always admit as one round / one phase, both
    through the planner and through the independent write coloring."""
    pool = BlockPool(sum(sizes), page_size=4)
    batch = [pool.alloc(s, owner=i) for i, s in enumerate(sizes)]
    sched, accesses = pool.admission_sched(batch)
    plan = lower(sched, 1)
    assert plan.nr_rounds == 1
    assert len(color_phases(accesses)) - 1 <= 1
    pool.plan_admission(batch)      # must not raise


@settings(max_examples=60, deadline=None)
@given(sizes=st.lists(st.integers(min_value=1, max_value=4),
                      min_size=2, max_size=6),
       a=st.integers(min_value=0), b=st.integers(min_value=0))
def test_forged_overlap_is_refused(sizes, a, b):
    """Hand the same page to two requests (bypassing alloc): the lowered
    plan needs >1 round and plan_admission raises — relabelling is
    injective, so canonicalisation cannot hide the conflict."""
    a, b = a % len(sizes), b % len(sizes)
    if a == b:
        b = (a + 1) % len(sizes)
    pool = BlockPool(sum(sizes), page_size=4)
    batch = [pool.alloc(s, owner=i) for i, s in enumerate(sizes)]
    batch[b] = list(batch[b]) + [batch[a][0]]       # forged double use
    sched, accesses = pool.admission_sched(batch)
    assert lower(sched, 1).nr_rounds > 1
    assert len(color_phases(accesses)) - 1 > 1
    with pytest.raises(AdmissionConflict):
        pool.plan_admission(batch)


# one lifecycle op against a miniature service model: submit a request,
# tick the service (admit head-of-line + decode + retire), preempt an
# active victim (pages back, requeued for re-admission — the robustness
# tier's eviction path), or cancel a live request outright
_LIFECYCLE = st.lists(
    st.tuples(st.sampled_from(["submit", "step", "preempt", "cancel"]),
              st.integers(min_value=0, max_value=7)),
    min_size=1, max_size=60)


@settings(max_examples=60, deadline=None)
@given(ops=_LIFECYCLE, n_pages=st.integers(min_value=6, max_value=20))
def test_lifecycle_interleavings_conserve_pages(ops, n_pages):
    """Arbitrary interleavings of admit/decode/preempt/cancel — the
    service's page-accounting protocol (head-of-line admission as a
    single conflict round, per-tick retire, preemption with requeue,
    cancellation from queue or slot) — conserve pages after *every* op
    and never hand a page to two live owners.  This is the invariant
    that makes preemption safe: a victim's pages go back intact and its
    re-admission is just another conflict round."""
    pool = BlockPool(n_pages, page_size=4)
    queue = []                      # (rid, pages needed, ticks left)
    active = {}                     # rid -> [pages, ticks left]
    rid, max_batch = 0, 3
    for kind, arg in ops:
        if kind == "submit":
            queue.append((rid, 1 + arg % 3, 1 + arg % 4))
            rid += 1
        elif kind == "step":
            batch = []
            while queue and len(active) + len(batch) < max_batch:
                r, need, budget = queue[0]
                if not pool.can_admit(need):
                    break           # head-of-line blocking, like _admit
                queue.pop(0)
                batch.append((r, pool.alloc(need, owner=r), budget))
            if batch:
                _, plan = pool.plan_admission([pg for _, pg, _ in batch])
                assert plan.nr_rounds == 1
                for r, pg, budget in batch:
                    active[r] = [pg, budget]
            for r in list(active):  # one decode tick; retire exhausted
                active[r][1] -= 1
                if active[r][1] <= 0:
                    pool.free(active.pop(r)[0])
        elif kind == "preempt" and active:
            r = sorted(active)[arg % len(active)]
            pg, budget = active.pop(r)
            pool.free(pg)
            queue.insert(0, (r, len(pg), budget))   # re-admit later
        elif kind == "cancel":
            live = sorted(active) + [q[0] for q in queue]
            if live:
                r = live[arg % len(live)]
                if r in active:
                    pool.free(active.pop(r)[0])
                else:
                    queue = [q for q in queue if q[0] != r]
        pool.check_invariants()
        claimed = [p for pg, _ in active.values() for p in pg]
        assert len(claimed) == len(set(claimed)), \
            "a page is assigned to two live requests"
        assert pool.allocated == len(claimed)
    for pg, _ in active.values():
        pool.free(pg)
    pool.check_invariants()
    assert pool.allocated == 0      # full drain returns every page


def test_exhaustion_and_double_free():
    pool = BlockPool(4, page_size=4)
    pages = pool.alloc(4, owner="r0")
    assert not pool.can_admit(1)
    with pytest.raises(MemoryError):
        pool.alloc(1, owner="r1")
    pool.free(pages)
    with pytest.raises(ValueError):
        pool.free(pages)            # double free is rejected
    pool.check_invariants()


def test_lifo_reuse():
    """Most-recently-freed pages are handed out first (hot reuse)."""
    pool = BlockPool(8, page_size=4)
    first = pool.alloc(2, owner="a")
    pool.free(first)
    again = pool.alloc(2, owner="b")
    assert set(again) == set(first)
