"""Observability tier: tracing round-trip, exact metric accounting,
Chrome/Perfetto export schema (DESIGN.md §Observability).

The paper's evaluation is itself an observability artifact — per-task
tic/toc timelines and exact overhead accounting — so these tests pin
(a) the disabled tracer really is a no-op, (b) spans/tasks/counters
survive the export round-trip as valid Chrome trace-event JSON, and
(c) the metric counts for a known QR plan match the analytic task counts
of the tile grid.
"""

import json
import threading

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       get_registry, get_tracer, set_tracer, disable,
                       to_chrome_trace, validate_chrome_trace,
                       write_chrome_trace)
from repro.obs.trace import NullTracer, Tracer, _NULL_SPAN


@pytest.fixture
def tracer():
    """A fresh recording tracer installed as the global default, restored
    to the no-op tracer afterwards."""
    tr = Tracer()
    old = get_tracer()
    set_tracer(tr)
    yield tr
    set_tracer(old)


# --------------------------------------------------------------------------
# tracer
# --------------------------------------------------------------------------

class TestTracer:
    def test_default_is_noop(self):
        disable()
        tr = get_tracer()
        assert isinstance(tr, NullTracer) and not tr.enabled
        # one shared singleton span; records never accumulate
        s1 = tr.span("a", x=1)
        s2 = tr.span("b")
        assert s1 is s2 is _NULL_SPAN
        with tr.span("c") as sp:
            sp.args["result"] = 42        # writable, discarded
        tr.task(0, 0, 0, 0.0, 1.0)
        tr.event_span("d", 0.0, 1.0)
        tr.counter("e", 3.0)
        tr.clear()
        assert tr.nr_records == 0

    def test_span_nesting_round_trip(self, tracer):
        with tracer.span("outer", n=1) as outer:
            with tracer.span("inner"):
                pass
            outer.args["late"] = True
        assert [s.name for s in tracer.spans] == ["inner", "outer"]
        inner, outer = tracer.spans
        assert (outer.depth, inner.depth) == (1, 2)
        assert outer.t0 <= inner.t0 <= inner.t1 <= outer.t1
        assert outer.args == {"n": 1, "late": True}
        assert outer.lane == threading.current_thread().name

    def test_task_counter_event_records(self, tracer):
        tracer.task(7, 2, 1, 0.5, 0.75)
        tracer.event_span("phase", 0.0, 1.0, lane="engine", k=3)
        tracer.counter("depth", 4, t=0.25)
        t = tracer.tasks[0]
        assert (t.tid, t.task_type, t.lane, t.t0, t.t1) == (7, 2, 1, 0.5, 0.75)
        assert tracer.spans[0].lane == "engine"
        assert tracer.counters[0].value == 4.0
        assert tracer.nr_records == 3
        tracer.clear()
        assert tracer.nr_records == 0

    def test_threaded_spans_keep_their_lanes(self, tracer):
        def work():
            with tracer.span("w"):
                pass
        ths = [threading.Thread(target=work, name=f"lane-{i}")
               for i in range(4)]
        for th in ths:
            th.start()
        for th in ths:
            th.join()
        assert sorted(s.lane for s in tracer.spans) == \
            [f"lane-{i}" for i in range(4)]
        assert all(s.depth == 1 for s in tracer.spans)


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------

class TestMetrics:
    def test_counter_exact(self):
        c = Counter("c")
        c.inc()
        c.inc(41)
        assert c.value == 42
        with pytest.raises(ValueError, match="negative"):
            c.inc(-1)
        c.reset()
        assert c.value == 0

    def test_gauge(self):
        g = Gauge("g")
        g.set(3.0)
        g.add(-1.5)
        assert g.value == 1.5
        g.reset()
        assert g.value == 0.0

    def test_histogram_exact_buckets(self):
        h = Histogram("h", buckets=(0.1, 1.0))
        for v in (0.05, 0.1, 0.5, 2.0):
            h.observe(v)
        s = h.summary()
        assert h.count == 4 and h.sum == pytest.approx(2.65)
        assert s["buckets"] == {"le_0.1": 2, "le_1": 1, "overflow": 1}
        assert (s["min"], s["max"]) == (0.05, 2.0)
        h.reset()
        assert h.summary() == {"count": 0, "sum": 0.0}

    def test_registry_get_or_create_and_kind_safety(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")
        reg.histogram("h", buckets=(1.0, 2.0))
        with pytest.raises(ValueError, match="bucket"):
            reg.histogram("h", buckets=(1.0, 3.0))
        reg.counter("x").inc(5)
        reg.gauge("g").set(2.5)
        snap = reg.snapshot()
        assert snap["x"] == 5 and snap["g"] == 2.5
        assert snap["h"] == {"count": 0, "sum": 0.0}
        reg.reset()
        assert reg.snapshot()["x"] == 0
        assert reg.names() == ["g", "h", "x"]


# --------------------------------------------------------------------------
# exporter
# --------------------------------------------------------------------------

def _populated_tracer():
    tr = Tracer()
    with tr.span("build", n=2):
        pass
    tr.task(0, 1, 0, 1.0, 2.0)
    tr.task(1, 1, 1, 1.5, 2.5, process="predicted")
    tr.counter("pool", 3, t=1.0)
    tr.counter("pool", 2, t=2.0)
    return tr


class TestExport:
    def test_chrome_schema_round_trip(self, tmp_path):
        tr = _populated_tracer()
        reg = MetricsRegistry()
        reg.counter("done").inc(2)
        path = str(tmp_path / "t.json")
        summary = write_chrome_trace(path, tr, registry=reg,
                                     type_names={1: "DECODE"})
        assert summary == validate_chrome_trace(path)
        assert summary["phases"]["X"] == 3
        assert summary["phases"]["C"] == 2
        assert summary["counter_tracks"] == ["pool"]
        assert summary["processes"] == ["measured", "predicted"]
        obj = json.load(open(path))
        assert obj["otherData"]["metrics"]["done"] == 2
        names = {e["name"] for e in obj["traceEvents"] if e["ph"] == "X"}
        assert {"build", "DECODE"} <= names
        # timestamps normalized to the earliest record, in microseconds
        ts = [e["ts"] for e in obj["traceEvents"] if e["ph"] != "M"]
        assert min(ts) == 0.0
        task = next(e for e in obj["traceEvents"]
                    if e.get("cat") == "task" and e["args"]["tid"] == 0)
        assert task["dur"] == pytest.approx(1e6)

    def test_processes_get_distinct_pids(self):
        obj = to_chrome_trace(_populated_tracer())
        pids = {}
        for e in obj["traceEvents"]:
            if e["ph"] == "M" and e["name"] == "process_name":
                pids[e["args"]["name"]] = e["pid"]
        assert set(pids) == {"measured", "predicted"}
        assert len(set(pids.values())) == 2

    @pytest.mark.parametrize("mutate,match", [
        (lambda e: e.pop("ts"), "missing required key"),
        (lambda e: e.update(ts=-5.0), "negative timestamp"),
        (lambda e: e.pop("dur"), "needs numeric 'dur'"),
        (lambda e: e.update(dur=-1.0), "negative duration"),
    ])
    def test_tampered_trace_rejected(self, mutate, match):
        obj = to_chrome_trace(_populated_tracer())
        bad = next(e for e in obj["traceEvents"] if e["ph"] == "X")
        mutate(bad)
        with pytest.raises(ValueError, match=match):
            validate_chrome_trace(obj)

    def test_counter_event_needs_numeric_args(self):
        obj = to_chrome_trace(_populated_tracer())
        bad = next(e for e in obj["traceEvents"] if e["ph"] == "C")
        bad["args"] = {"value": "three"}
        with pytest.raises(ValueError, match="numeric args"):
            validate_chrome_trace(obj)


# --------------------------------------------------------------------------
# instrumented layers: exact accounting + timelines for a known QR plan
# --------------------------------------------------------------------------

def _qr_type_counts(mt, nt):
    """Analytic task counts of the mt x nt tiled-QR graph."""
    from repro.apps import qr
    k = range(min(mt, nt))
    return {
        qr.T_GEQRF: len(list(k)),
        qr.T_LARFT: sum(nt - kk - 1 for kk in k),
        qr.T_TSQRF: sum(mt - kk - 1 for kk in k),
        qr.T_SSRFT: sum((mt - kk - 1) * (nt - kk - 1) for kk in k),
    }


class TestQRAccounting:
    def test_executor_counts_match_tile_grid(self, tracer):
        """Running the 3x3-tile QR graph must execute exactly the
        analytic per-type task counts (GEQRF 3, LARFT 3, TSQRF 3,
        SSRFT 5), tallied on the executor and as registry deltas, with
        one task record each on the tracer."""
        import jax.numpy as jnp

        from repro.apps import qr

        counts = _qr_type_counts(3, 3)
        total = sum(counts.values())
        reg = get_registry()
        before = {tt: reg.counter(f"executor.tasks.type{tt}").value
                  for tt in counts}
        before_total = reg.counter("executor.tasks_executed").value

        a = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((96, 96)), jnp.float32)
        r, sched = qr.run_qr(a, tile=32, mode="sequential", backend="ref")

        for tt, n in counts.items():
            assert (reg.counter(f"executor.tasks.type{tt}").value
                    - before[tt]) == n
        assert (reg.counter("executor.tasks_executed").value
                - before_total) == total
        assert len(tracer.tasks) == total == sched.nr_tasks
        by_type = {}
        for t in tracer.tasks:
            by_type[t.task_type] = by_type.get(t.task_type, 0) + 1
            assert t.t1 >= t.t0 and t.lane == 0
        assert by_type == counts

    def test_plan_spans_recorded(self, tracer):
        from repro.apps import qr
        from repro.core import lower
        from repro.core.plan import clear_plan_cache, plan_cache_info

        s, _ = qr.make_qr_graph(3, 3)
        clear_plan_cache()
        plan = lower(s, 4)
        lower(s, 4)                             # cache hit: no new span
        info = plan_cache_info()
        assert (info["hits"], info["misses"]) == (1, 1)
        names = [sp.name for sp in tracer.spans]
        assert names.count("plan.lower") == 1
        assert "core.prepare" in names
        sp = next(sp for sp in tracer.spans if sp.name == "plan.lower")
        assert sp.args["tasks"] == s.nr_tasks
        assert sp.args["rounds"] == plan.nr_rounds


class TestLockFailureAccounting:
    def _conflicting_sched(self):
        from repro.core.graph import QSched
        s = QSched(nr_queues=2)
        r = s.addres()
        for _ in range(2):
            s.addlock(s.addtask(type=0, data=None), r)
        return s

    def test_simulated_contention_counts_failures(self):
        from repro.core.simulator import simulate
        s = self._conflicting_sched()
        simulate(s, 2)
        # two ready tasks, one shared resource, two workers: the second
        # worker's gettask must fail the lock at least once
        assert s.lock_failures >= 1
        s.start(threaded=False)
        assert s.lock_failures == 0      # reset like the rest of run state

    def test_threaded_executor_exposes_per_run_failures(self):
        from repro.core.executors import ThreadedExecutor
        s = self._conflicting_sched()
        ex = ThreadedExecutor(s, 2)
        reg = get_registry()
        before = reg.counter("executor.lock_failures").value
        ex.run(lambda tt, data: None)
        assert ex.lock_failures == s.lock_failures >= 0
        assert (reg.counter("executor.lock_failures").value - before
                ) == ex.lock_failures
        assert ex.type_counts == {0: 2}
        first = ex.lock_failures
        s2 = self._conflicting_sched()
        ex2 = ThreadedExecutor(s2, 2)
        ex2.run(lambda tt, data: None)   # fresh run: fresh accounting
        assert ex2.lock_failures == s2.lock_failures
        del first


# --------------------------------------------------------------------------
# serving tier
# --------------------------------------------------------------------------

class TestServiceObservability:
    @pytest.fixture(scope="class")
    def cfg_params(self):
        import jax
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("qwen3-1.7b").reduced()
        return cfg, lm.init_params(jax.random.PRNGKey(0), cfg)

    def test_stats_dict_and_metrics_registry(self, cfg_params):
        from repro.serve import GenerateService
        cfg, params = cfg_params
        svc = GenerateService(params, cfg, max_batch=2, max_seq=16,
                              page_size=4)
        prompt = np.arange(5, dtype=np.int32) % cfg.vocab
        svc.submit(prompt, 3)
        svc.submit(prompt, 3)
        svc.run_until_complete()
        # dict-shaped accessor stays backward-compatible
        assert svc.stats["submitted"] == svc.stats["admitted"] == 2
        assert svc.stats["retired"] == 2
        assert svc.stats["generated_tokens"] == 6
        # same counts live on the typed per-service registry
        snap = svc.metrics.snapshot()
        assert snap["serve.retired"] == 2
        assert snap["serve.ttft_s"]["count"] == 2
        assert snap["serve.latency_s"]["count"] == 2
        assert snap["serve.pages_in_use"] == 0.0    # drained
        for h in (svc.metrics.histogram("serve.ttft_s"),
                  svc.metrics.histogram("serve.latency_s")):
            assert h.sum > 0.0

    def test_request_lifecycle_trace(self, cfg_params, tracer, tmp_path):
        from repro.serve import GenerateService
        cfg, params = cfg_params
        svc = GenerateService(params, cfg, max_batch=2, max_seq=16,
                              page_size=4)
        prompt = np.arange(5, dtype=np.int32) % cfg.vocab
        reqs = [svc.submit(prompt, 3), svc.submit(prompt, 3)]
        svc.run_until_complete()
        for r in reqs:
            assert r.t_submit <= r.t_admit <= r.t_first <= r.t_done
            assert r.latency_s >= r.ttft_s > 0.0
        span_names = {s.name for s in tracer.spans}
        # no "plan.lower" here: the decode/admission shapes were lowered
        # (and cached) by the untraced test above — cache hits re-emit no
        # lowering span, by design
        assert {"request.queued", "request.prefill", "request.decode",
                "request", "engine.execute"} <= span_names
        lanes = {s.lane for s in tracer.spans
                 if s.name == "request"}
        assert lanes == {f"req {r.rid}" for r in reqs}

        path = str(tmp_path / "serve.json")
        summary = write_chrome_trace(path, registry=svc.metrics)
        assert {"serve.pages_in_use", "serve.queue_depth"} <= \
            set(summary["counter_tracks"])
        assert "requests" in summary["processes"]
