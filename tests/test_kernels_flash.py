"""Flash-attention Pallas kernel: interpret-mode validation vs the plain
softmax oracle and the model's chunked-jnp path, shape/dtype sweep +
property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels.flash_attention import kernel, ops, ref


def qkv(bh, sq, sk, hd, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    mk = lambda s: jnp.asarray(rng.standard_normal(s) * 0.5, dtype)
    return mk((bh, sq, hd)), mk((bh, sk, hd)), mk((bh, sk, hd))


@pytest.mark.parametrize("sq,sk,blocks", [(128, 128, (64, 64)),
                                          (256, 256, (128, 64)),
                                          (256, 256, (64, 128)),
                                          (512, 512, (128, 128))])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_ref(sq, sk, blocks, causal):
    q, k, v = qkv(4, sq, sk, 64, seed=sq + sk)
    got = kernel.flash_attention(q, k, v, causal=causal, block_q=blocks[0],
                                 block_k=blocks[1], interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    q, k, v = qkv(2, 128, 128, 32, seed=1, dtype=dtype)
    got = kernel.flash_attention(q, k, v, interpret=True)
    want = ref.attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    atol=tol, rtol=tol)


def test_bshd_wrapper_pads_ragged_seq():
    b, s, h, hd = 2, 100, 3, 32      # s not a block multiple
    rng = np.random.default_rng(3)
    mk = lambda shape: jnp.asarray(rng.standard_normal(shape) * 0.5,
                                   jnp.float32)
    q, k, v = mk((b, s, h, hd)), mk((b, s, h, hd)), mk((b, s, h, hd))
    got = ops.flash_attention_bshd(q, k, v, block_q=64, block_k=64)
    want = ops.attention_ref_bshd(q, k, v)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)


def test_matches_model_chunked_path():
    """Same math as the model's pure-jnp online-softmax attention."""
    from repro.models.layers import sdpa_chunked
    b, s, h, hd = 2, 256, 4, 32
    rng = np.random.default_rng(5)
    mk = lambda: jnp.asarray(rng.standard_normal((b, s, h, hd)) * 0.5,
                             jnp.float32)
    q, k, v = mk(), mk(), mk()
    got = ops.flash_attention_bshd(q, k, v, block_q=64, block_k=64)
    want = sdpa_chunked(q, k, v, chunk=64)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([64, 128]), hd=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 999), causal=st.booleans())
def test_property_flash(sq, hd, seed, causal):
    q, k, v = qkv(2, sq, sq, hd, seed=seed)
    got = kernel.flash_attention(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5, rtol=5e-4)


def test_softmax_rows_sum_to_one_property():
    """With v = all-ones, the output must be exactly ones (softmax weights
    sum to 1 regardless of blocking)."""
    q, k, _ = qkv(2, 128, 128, 32, seed=9)
    v = jnp.ones((2, 128, 32), jnp.float32)
    got = kernel.flash_attention(q, k, v, causal=True, interpret=True,
                                 block_q=64, block_k=64)
    assert_allclose(np.asarray(got), np.ones_like(got), atol=1e-5)
