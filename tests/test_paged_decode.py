"""Paged-attention decode path conformance.

The service exposes three decode round functions — ``kernel`` (the Pallas
page-walk megakernel, interpret mode on CPU), ``bounded`` (window-bounded
jitted gather, the CPU default), ``gather`` (PR 6's full-window path, the
oracle).  All three must be token-for-token identical per request across
page sizes, ragged positions, and mid-stream joins/leaves, for dense GQA
and MoE+MLA alike; the SSM family must resolve to ``gather`` untouched.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import GenerateService, SamplingParams

MAX_SEQ = 16
PLENS = (3, 5, 3, 6)
BUDGETS = (3, 6, 2, 4)          # ragged, forces mid-stream leaves


def _run_service(params, cfg, prompts, budgets, *, decode_path,
                 page_size, **kw):
    # max_batch < n_requests forces mid-stream joins as slots free up
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=page_size, decode_path=decode_path,
                          **kw)
    handles = [svc.submit(p, n) for p, n in zip(prompts, budgets)]
    svc.run_until_complete()
    assert all(h.done for h in handles)
    assert svc.pool.allocated == 0
    return [h.generated for h in handles]


def _setup(arch, over):
    cfg = get_config(arch).reduced(**over)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, size=pl, dtype=np.int32)
               for pl in PLENS]
    return cfg, params, prompts


@pytest.mark.parametrize("arch,over", [
    ("qwen3-1.7b", {}),                              # dense GQA
    ("deepseek-v3-671b", {"capacity_factor": 8.0}),  # moe + mla
])
@pytest.mark.parametrize("page_size", [4, 8])
def test_kernel_and_bounded_match_gather(arch, over, page_size):
    cfg, params, prompts = _setup(arch, over)
    oracle = _run_service(params, cfg, prompts, BUDGETS,
                          decode_path="gather", page_size=page_size)
    for path in ("bounded", "kernel"):
        got = _run_service(params, cfg, prompts, BUDGETS,
                           decode_path=path, page_size=page_size)
        assert got == oracle, f"{path} diverged from gather ({arch}, " \
                              f"page_size={page_size})"


def test_resolved_path_reported():
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    for path in ("kernel", "bounded", "gather"):
        svc = GenerateService(params, cfg, max_seq=MAX_SEQ, page_size=4,
                              decode_path=path)
        assert svc.decode_path == path
    auto = GenerateService(params, cfg, max_seq=MAX_SEQ, page_size=4)
    # auto resolves via the backend capability probe: kernel only where
    # the engine compiles Pallas natively, bounded elsewhere
    from repro.core.backends import get_backend
    want = "kernel" if get_backend("engine").compiled_kernels() else "bounded"
    assert auto.decode_path == want
    with pytest.raises(ValueError, match="decode_path"):
        GenerateService(params, cfg, decode_path="warp")


def test_ssm_forces_gather_and_still_conforms():
    """The SSM family has O(1) state — no page table to walk.  Forcing
    the kernel path must quietly resolve to gather and stay correct."""
    cfg, params, prompts = _setup("falcon-mamba-7b", {})
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4, decode_path="kernel")
    assert svc.decode_path == "gather"
    oracle = _run_service(params, cfg, prompts, BUDGETS,
                          decode_path="auto", page_size=4)
    handles = [svc.submit(p, n) for p, n in zip(prompts, BUDGETS)]
    svc.run_until_complete()
    assert [h.generated for h in handles] == oracle


@pytest.mark.parametrize("path", ["bounded", "gather"])
def test_sampling_deterministic_and_per_request(path):
    """temperature>0 sampling must be reproducible under a fixed seed and
    independent of scheduling: the same (seed, rid, prompt) produces the
    same stream on every decode path and at any batch composition."""
    cfg, params, prompts = _setup("qwen3-1.7b", {})
    sp = SamplingParams(temperature=0.8, top_k=8, seed=7)
    a = _run_service(params, cfg, prompts, BUDGETS, decode_path=path,
                     page_size=4, sampling=sp)
    b = _run_service(params, cfg, prompts, BUDGETS, decode_path=path,
                     page_size=4, sampling=sp)
    assert a == b, "fixed seed must reproduce the streams"
    greedy = _run_service(params, cfg, prompts, BUDGETS, decode_path=path,
                          page_size=4)
    assert a != greedy, "tempered sampling should diverge from greedy"


def test_sampling_stream_independent_of_batch_composition():
    """Per-request fold_in(seed, rid) keys: a request's sampled stream
    must not change when it runs alone vs continuously batched."""
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=4, dtype=np.int32)
    sp = SamplingParams(temperature=0.7, top_k=0, seed=11)
    solo = GenerateService(params, cfg, max_batch=1, max_seq=MAX_SEQ,
                           page_size=4, sampling=sp)
    h_solo = solo.submit(prompt, 5)
    solo.run_until_complete()
    batched = GenerateService(params, cfg, max_batch=3, max_seq=MAX_SEQ,
                              page_size=4, sampling=sp)
    h0 = batched.submit(prompt, 5)      # rid 0 in both services
    batched.submit(prompt[:3], 4)
    batched.submit(prompt, 6)
    batched.run_until_complete()
    assert h0.generated == h_solo.generated


def test_batched_prefill_entry_points_and_conformance():
    """Same-length prompts admitted in one conflict round share one
    batched prefill entry point — and produce the same first tokens the
    one-at-a-time path produces."""
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=5, dtype=np.int32)
               for _ in range(3)]
    svc = GenerateService(params, cfg, max_batch=3, max_seq=MAX_SEQ,
                          page_size=4)
    hs = [svc.submit(p, 3) for p in prompts]
    svc.run_until_complete()
    eps = svc.compiled_entry_points()
    assert (5, 3) in eps["prefill_shapes"], \
        "3 same-length prompts should compile one (plen=5, nb=3) entry"
    assert eps["prefill_plens"] == [5]
    # one-at-a-time oracle: admit each into its own service
    for h, p in zip(hs, prompts):
        ref = GenerateService(params, cfg, max_batch=1, max_seq=MAX_SEQ,
                              page_size=4)
        hr = ref.submit(p, 3)
        ref.run_until_complete()
        assert h.generated == hr.generated


def test_pages_attended_counter():
    """serve.pages_attended counts the per-tick page-walk work: the sum
    over active slots of pos//page_size + 1 — strictly less than the
    full-window bound whenever sequences are shorter than max_seq."""
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4)
    svc.submit(np.arange(3, dtype=np.int32) % cfg.vocab, 4)
    svc.run_until_complete()
    attended = svc.stats["pages_attended"]
    # 3 decode ticks at pos 3,4,5 with page_size 4 -> 1+2+2 pages
    assert attended == 5
    full_window = 3 * (MAX_SEQ // 4)
    assert attended < full_window
