"""Property tests for the paged-attention decode kernels.

The access contract (``kernels/paged_attention/ops.py``): for slot ``t``
the kernel may touch ONLY the pages listed in
``page_rows[t, : pos[t]//page_size + 1]``.  We enforce it the blunt way —
every pool page *not* listed in any slot's walked prefix is poisoned with
NaN, and every unlisted page-table tail entry points at a poisoned page.
If the kernel ever reads outside its walk, NaN propagates through the
softmax and the (finite) comparison against the jnp reference fails.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels.paged_attention import (paged_gqa_decode,
                                           paged_gqa_decode_ref,
                                           paged_mla_decode,
                                           paged_mla_decode_ref)


@st.composite
def layouts(draw):
    """A random paged layout: disjoint per-slot page lists plus ragged
    positions, with enough spare pages that some are never listed."""
    bs = draw(st.integers(1, 3))
    page_size = draw(st.sampled_from([4, 8]))
    max_pages = draw(st.integers(2, 4))
    n_pages = bs * max_pages + draw(st.integers(1, 3))   # spare pages
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_pages)
    pos = np.array([draw(st.integers(0, max_pages * page_size - 1))
                    for _ in range(bs)], np.int32)
    page_rows = np.zeros((bs, max_pages), np.int32)
    walked = set()
    k = 0
    for t in range(bs):
        n_walk = pos[t] // page_size + 1
        page_rows[t, :n_walk] = perm[k:k + n_walk]
        walked.update(int(p) for p in perm[k:k + n_walk])
        k += n_walk
        # the tail of the page table points at pages the slot does NOT
        # occupy yet — they are poisoned, so reading them is detected
        page_rows[t, n_walk:] = perm[-1]
    return bs, page_size, max_pages, n_pages, page_rows, pos, walked, seed


def _poison(pool, walked):
    """NaN every page not in any slot's walked prefix."""
    mask = np.ones(pool.shape[0], bool)
    mask[list(walked)] = False
    pool = np.asarray(pool).copy()
    pool[mask] = np.nan
    return jnp.asarray(pool)


def _gqa_case(layout):
    bs, ps, mp, n_pages, page_rows, pos, walked, seed = layout
    rng = np.random.default_rng(seed + 1)
    n_heads, n_kv, hd = 4, 2, 8
    mk = lambda s: jnp.asarray(rng.standard_normal(s) * 0.5, jnp.float32)
    q = mk((bs, n_heads, hd))
    k_new, v_new = mk((bs, n_kv, hd)), mk((bs, n_kv, hd))
    k_pool = _poison(mk((n_pages, ps, n_kv, hd)), walked)
    v_pool = _poison(mk((n_pages, ps, n_kv, hd)), walked)
    pr, po = jnp.asarray(page_rows), jnp.asarray(pos)
    o, kp, vp = paged_gqa_decode(q, k_new, v_new, k_pool, v_pool, pr, po,
                                 page_size=ps, interpret=True)
    assert np.isfinite(np.asarray(o)).all(), \
        "kernel read a poisoned (unlisted) page"
    ro, rk, rv = paged_gqa_decode_ref(q, k_new, v_new, k_pool, v_pool,
                                      pr, po, page_size=ps)
    assert_allclose(np.asarray(o), np.asarray(ro), atol=1e-5, rtol=1e-5)
    # the write side of the contract: exactly the walked cells match the
    # reference pools (poisoned pages stay poisoned in both)
    for got, want in ((kp, rk), (vp, rv)):
        got, want = np.asarray(got), np.asarray(want)
        for t in range(bs):
            n_walk = pos[t] // ps + 1
            pages = page_rows[t, :n_walk]
            assert_allclose(got[pages], want[pages], atol=0, rtol=0)


def _mla_case(layout):
    bs, ps, mp, n_pages, page_rows, pos, walked, seed = layout
    rng = np.random.default_rng(seed + 2)
    n_heads, lat, rope = 4, 16, 8
    mk = lambda s: jnp.asarray(rng.standard_normal(s) * 0.5, jnp.float32)
    q_eff, q_rope = mk((bs, n_heads, lat)), mk((bs, n_heads, rope))
    c_new, r_new = mk((bs, lat)), mk((bs, rope))
    c_pool = _poison(mk((n_pages, ps, lat)), walked)
    r_pool = _poison(mk((n_pages, ps, rope)), walked)
    pr, po = jnp.asarray(page_rows), jnp.asarray(pos)
    scale = (lat + rope) ** -0.5
    ctx, cp, rp = paged_mla_decode(q_eff, q_rope, c_new, r_new, c_pool,
                                   r_pool, pr, po, page_size=ps,
                                   scale=scale, interpret=True)
    assert np.isfinite(np.asarray(ctx)).all(), \
        "kernel read a poisoned (unlisted) page"
    rctx, rc, rr = paged_mla_decode_ref(q_eff, q_rope, c_new, r_new,
                                        c_pool, r_pool, pr, po,
                                        page_size=ps, scale=scale)
    assert_allclose(np.asarray(ctx), np.asarray(rctx), atol=1e-5, rtol=1e-5)
    for got, want in ((cp, rc), (rp, rr)):
        got, want = np.asarray(got), np.asarray(want)
        for t in range(bs):
            n_walk = pos[t] // ps + 1
            pages = page_rows[t, :n_walk]
            assert_allclose(got[pages], want[pages], atol=0, rtol=0)


@settings(max_examples=12, deadline=None)
@given(layouts())
def test_gqa_kernel_never_reads_unlisted_pages(layout):
    _gqa_case(layout)


@settings(max_examples=12, deadline=None)
@given(layouts())
def test_mla_kernel_never_reads_unlisted_pages(layout):
    _mla_case(layout)
