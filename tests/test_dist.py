"""Distributed-optimization tricks: int8 gradient compression with error
feedback, and ring collective-matmuls.  Multi-device semantics run in a
subprocess with 8 forced host devices (the test process itself keeps 1)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.dist.compression import (compressed_psum, dequantize_int8,
                                    init_error_feedback, quantize_int8)


def run_multidevice(body: str) -> str:
    """Run ``body`` with 8 forced host devices; returns stdout."""
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n"
              "import sys; sys.path.insert(0, 'src')\n"
              + textwrap.dedent(body))
    out = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestQuantization:
    def test_roundtrip_error_bound(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-7

    def test_error_feedback_unbiased_over_time(self):
        """EF-SGD property: accumulated compressed updates converge to the
        true sum (the residual never escapes)."""
        rng = np.random.default_rng(1)
        g_seq = [jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)
                 for _ in range(200)]
        ef = {"g": jnp.zeros(64)}
        acc = jnp.zeros(64)
        for g in g_seq:
            out, ef = compressed_psum({"g": g}, ef)
            acc = acc + out["g"]
        true = sum(np.asarray(g) for g in g_seq)
        resid = np.asarray(ef["g"])
        assert_allclose(np.asarray(acc) + resid, true, atol=1e-4)

    def test_ef_sgd_converges_on_quadratic(self):
        """Compressed SGD with EF reaches the optimum of a quadratic."""
        w = jnp.ones(32) * 5.0
        ef = {"w": jnp.zeros(32)}
        for _ in range(300):
            g = 2 * w                 # d/dw ||w||^2
            out, ef = compressed_psum({"w": g}, ef)
            w = w - 0.05 * out["w"]
        assert float(jnp.max(jnp.abs(w))) < 1e-2


class TestMultiDevice:
    def test_compressed_psum_matches_exact(self):
        out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax import shard_map
        from repro.dist.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("dp",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)
        ef = jnp.zeros((8, 128), jnp.float32)

        def f(g, e):
            out, ef2 = compressed_psum({"g": g[0]}, {"g": e[0]},
                                       axis_name="dp")
            return out["g"][None], ef2["g"][None]

        fm = shard_map(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                       out_specs=(P("dp"), P("dp")))
        out, ef2 = jax.jit(fm)(g, ef)
        exact = np.asarray(g).sum(0)
        got = np.asarray(out)[0]          # every rank has the same psum
        rel = np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9)
        print("REL", rel)
        assert (np.asarray(out) == np.asarray(out)[0:1]).all()
        """)
        rel = float(out.split("REL")[1].split()[0])
        assert rel < 2e-2, f"compressed psum too lossy: {rel}"

    def test_allgather_matmul_exact(self):
        out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax import shard_map
        from repro.dist.collective import allgather_matmul
        mesh = jax.make_mesh((8,), ("tp",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)

        def f(xl, w):
            return allgather_matmul(xl, w, "tp", 8)

        fm = shard_map(f, mesh=mesh, in_specs=(P("tp", None), P(None, None)),
                       out_specs=P(None, None), check_vma=False)
        got = jax.jit(fm)(x, w)
        err = float(jnp.abs(got - x @ w).max())
        print("ERR", err)
        """)
        err = float(out.split("ERR")[1].split()[0])
        assert err < 1e-4

    def test_reducescatter_matmul_exact(self):
        out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax import shard_map
        from repro.dist.collective import reducescatter_matmul
        mesh = jax.make_mesh((8,), ("tp",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)  # (m, k)
        w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)  # (k, n)

        def f(xl, wl):
            # xl: (m, k/8); wl: (k/8, n) → partial sums reduce-scattered
            return reducescatter_matmul(xl, wl, "tp", 8)

        fm = shard_map(f, mesh=mesh,
                       in_specs=(P(None, "tp"), P("tp", None)),
                       out_specs=P("tp", None))
        got = jax.jit(fm)(x, w)
        err = float(jnp.abs(got - x @ w).max())
        print("ERR", err)
        """)
        err = float(out.split("ERR")[1].split()[0])
        assert err < 1e-3
