"""Shared test configuration.

* Optional-dependency gating: the five hypothesis-based suites are skipped
  at collection (``pytest.importorskip`` semantics, applied conftest-wide
  via ``collect_ignore``) when ``hypothesis`` is not installed, instead of
  erroring the whole collection.  ``pip install -e .[dev]`` brings it in.
* Subprocess environment: test_dist.py / test_dryrun_small.py re-launch
  ``sys.executable`` for multi-device cells; make sure the inherited
  PYTHONPATH carries ``src`` (absolute) so ``repro`` — and the
  sitecustomize jax-compat shim — resolve in the children regardless of
  how this pytest process itself found them.
"""

import importlib.util
import os
import pathlib

_HYPOTHESIS_SUITES = [
    "test_blockpool_properties.py",
    "test_core_locks.py",
    "test_core_sched.py",
    "test_engine_properties.py",
    "test_kernels_flash.py",
    "test_kernels_nbody.py",
    "test_kernels_qr.py",
    "test_paged_properties.py",
]

collect_ignore = ([] if importlib.util.find_spec("hypothesis") is not None
                  else list(_HYPOTHESIS_SUITES))

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
_paths = [p for p in os.environ.get("PYTHONPATH", "").split(os.pathsep) if p]
if _SRC not in {os.path.abspath(p) for p in _paths}:
    os.environ["PYTHONPATH"] = os.pathsep.join([_SRC] + _paths)
