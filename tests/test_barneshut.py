"""Barnes-Hut application tests (paper §4.2): octree invariants, exact
interaction-partition coverage, accuracy vs direct sum, hierarchical
conflicts under the threaded executor, structural counts."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.apps import barneshut as bh
from repro.core import simulate
from repro.kernels.nbody import ref


def cloud(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.random((n, 3)), rng.random(n) + 0.5


def lattice(side):
    """side³ particles at cell centres — deterministic octree shape."""
    g = (np.arange(side) + 0.5) / side
    x = np.stack(np.meshgrid(g, g, g, indexing="ij"), -1).reshape(-1, 3)
    return x, np.ones(len(x))


class TestOctree:
    def test_contiguous_ranges(self):
        x, m = cloud(500, 1)
        t = bh.Octree(x, m, n_max=32)
        for c in t.cells:
            if c.split:
                assert sum(t.cells[k].count for k in c.children) == c.count
                starts = sorted(t.cells[k].start for k in c.children)
                assert starts[0] == c.start
        # particles in each leaf really are inside the leaf's box
        for c in t.cells:
            if not c.split:
                xs = t.x[:, c.start:c.start + c.count]
                for d in range(3):
                    assert (xs[d] >= c.loc[d] - 1e-12).all()
                    assert (xs[d] <= c.loc[d] + c.h + 1e-12).all()

    def test_leaf_counts_bounded(self):
        x, m = cloud(2000, 2)
        t = bh.Octree(x, m, n_max=50)
        for c in t.cells:
            if not c.split:
                assert c.count <= 50

    def test_lattice_structure(self):
        """32³ lattice, n_max=64: uniform depth-3 leaves (512 cells of 64)."""
        x, m = lattice(32)
        t = bh.Octree(x, m, n_max=64)
        leaves = [c for c in t.cells if not c.split]
        assert len(leaves) == 512
        assert all(c.count == 64 for c in leaves)
        assert len(t.cells) == 1 + 8 + 64 + 512


class TestGraphStructure:
    def test_lattice_task_counts(self):
        """Deterministic analogue of the paper's 1M-particle counts: on a
        4³ grid of stop cells the 26-neighbourhood gives
        3·(3·4·4) + 6·(3·3·4) + 4·(3·3·3) = 468 pair tasks."""
        x, m = lattice(32)
        t = bh.Octree(x, m, n_max=64)
        g = bh.build_graph(t, n_task=2000)
        assert g.counts["self"] == 64          # stop cells at depth 2
        assert g.counts["pair_pp"] == 468
        assert g.counts["pair_pc"] == 512      # one per leaf
        assert g.counts["com"] == len(t.cells)
        assert g.counts["resources"] == len(t.cells)
        # locks: self 1 + pair 2 + pc 1 (the paper's 43 416 formula)
        assert g.counts["locks"] == 64 + 2 * 468 + 512

    def test_hierarchical_resources(self):
        x, m = cloud(800, 3)
        t = bh.Octree(x, m, n_max=64)
        g = bh.build_graph(t, n_task=256)
        s = g.sched
        for c in t.cells:
            if c.parent != -1:
                assert s.resources[c.res].parent == t.cells[c.parent].res

    def test_exact_pair_coverage(self):
        """THE partition invariant: every directed particle pair (p,q) is
        covered exactly once — directly (self/pair blocks) or via the COM
        of exactly one cell containing q in p's leaf list."""
        x, m = cloud(300, 4)
        t = bh.Octree(x, m, n_max=16)
        g = bh.build_graph(t, n_task=64)
        n = t.n
        cover = np.zeros((n, n), dtype=np.int32)

        def rng(cid):
            c = t.cells[cid]
            return slice(c.start, c.start + c.count)

        for tid, cells in g.self_blocks.items():
            for c in cells:
                r = rng(c)
                cover[r, r] += 1
        for pairs in list(g.self_pairs.values()) + list(g.pair_pairs.values()):
            for a, b in pairs:
                cover[rng(a), rng(b)] += 1
                cover[rng(b), rng(a)] += 1
        for tid, srcs in g.pc_lists.items():
            kind, leaf = g.task_cell[tid]
            for src in srcs:
                cover[rng(leaf), rng(src)] += 1
        np.fill_diagonal(cover, 1)
        assert (cover == 1).all(), (
            f"coverage broken: min={cover.min()} max={cover.max()}")

    def test_com_deps_bottom_up(self):
        x, m = cloud(500, 5)
        t = bh.Octree(x, m, n_max=32)
        g = bh.build_graph(t, n_task=128)
        s = g.sched
        for c in t.cells:
            if c.parent != -1:
                assert t.cells[c.parent].task_com in s.tasks[c.task_com].unlocks


class TestNumerics:
    def test_accuracy_vs_direct(self):
        x, m = cloud(1500, 6)
        acc, st, g = bh.solve(x, m, n_max=32, n_task=256, backend="ref")
        exact = ref.acc_direct_ref(st.x, st.m)
        num = np.linalg.norm(np.asarray(acc) - np.asarray(exact), axis=0)
        den = np.linalg.norm(np.asarray(exact), axis=0)
        rel = num / np.maximum(den, 1e-12)
        assert np.median(rel) < 2e-2, f"median rel err {np.median(rel)}"
        assert rel.mean() < 5e-2

    def test_direct_limit_exact(self):
        """With n_max >= N the tree is one leaf: pure direct sum → matches
        the O(N²) oracle to float tolerance."""
        x, m = cloud(200, 7)
        acc, st, _ = bh.solve(x, m, n_max=256, n_task=512, backend="ref")
        exact = ref.acc_direct_ref(st.x, st.m)
        assert_allclose(np.asarray(acc), np.asarray(exact), rtol=2e-4,
                        atol=1e-5)

    def test_pallas_backend_agrees(self):
        x, m = cloud(600, 8)
        a1, st1, _ = bh.solve(x, m, n_max=32, n_task=128, backend="ref")
        a2, st2, _ = bh.solve(x, m, n_max=32, n_task=128, backend="pallas")
        assert_allclose(np.asarray(a1), np.asarray(a2), rtol=2e-3, atol=1e-4)

    def test_threaded_matches_sequential(self):
        """4 worker threads with real hierarchical locks, in-place numpy
        accumulation: locks alone must prevent lost updates."""
        x, m = cloud(1200, 9)
        tree = bh.Octree(x, m, n_max=32)
        g1 = bh.build_graph(tree, n_task=128, nr_queues=1)
        st1 = bh.BHState(g1, backend="ref")
        st1.run("sequential")
        tree2 = bh.Octree(x, m, n_max=32)
        g2 = bh.build_graph(tree2, n_task=128, nr_queues=4)
        st2 = bh.BHState(g2, backend="ref", accumulate="numpy")
        st2.run("threaded", nr_workers=4)
        assert_allclose(np.asarray(st1.result()), np.asarray(st2.result()),
                        rtol=1e-3, atol=1e-4)

    def test_momentum_roughly_conserved(self):
        x, m = cloud(800, 10)
        acc, st, _ = bh.solve(x, m, n_max=32, n_task=128, backend="ref")
        p = np.asarray(acc) @ np.asarray(st.m)
        scale = float(jnp.abs(acc).max() * jnp.sum(st.m))
        assert np.abs(p).max() < 5e-2 * scale


class TestScheduling:
    def test_simulated_scaling(self):
        """Paper Fig 11: ~90% parallel efficiency at 32 cores (scheduler
        limited; the >32-core falloff is hardware, not scheduling).  The
        paper's granularity gives ≥8 stop cells per worker (512 cells / 64
        cores); mirror that ratio here — with too-coarse tasks the per-cell
        conflict chain, not the scheduler, bounds the makespan (that
        granularity trade-off is the paper's §2 discussion)."""
        x, m = cloud(20000, 11)

        def make(n):
            t2 = bh.Octree(x, m, n_max=64)
            return bh.build_graph(t2, n_task=256, nr_queues=n).sched

        r1 = simulate(make(1), 1)
        r32 = simulate(make(32), 32)
        eff = r1.makespan / (32 * r32.makespan)
        assert eff > 0.80, f"32-worker efficiency {eff:.3f}"

    def test_schedule_valid(self):
        x, m = cloud(3000, 12)
        tree = bh.Octree(x, m, n_max=64)
        g = bh.build_graph(tree, n_task=512, nr_queues=8)
        res = simulate(g.sched, 8)
        g.sched.validate_schedule(res.timeline)
