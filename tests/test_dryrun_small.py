"""Dry-run machinery validation on a small mesh (subprocess with 8 forced
host devices): shardings apply, compile succeeds for every family, the
depth extrapolation matches a fully-unrolled ground truth, and the
collective parser agrees with the HLO."""

import json
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun import SHAPES, collective_stats, depth_variants, skip_reason
from repro.configs import ARCH_IDS, get_config


def run_py(body: str) -> str:
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n"
              "import sys; sys.path.insert(0, 'src')\n"
              + textwrap.dedent(body))
    out = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stderr[-3000:] or out.stdout[-2000:])
    return out.stdout


class TestCollectiveParser:
    def test_parses_known_hlo(self):
        hlo = """
  %ag = bf16[64,128]{1,0} all-gather(bf16[8,128]{1,0} %x), dimensions={0}
  %ar = f32[32]{0} all-reduce(f32[32]{0} %y), to_apply=%sum
  %aa = f32[4,16]{1,0} all-to-all(f32[4,16]{1,0} %z), dimensions={0}
"""
        st = collective_stats(hlo)
        assert st["all-gather"]["count"] == 1
        assert st["all-gather"]["operand_bytes"] == 8 * 128 * 2
        assert st["all-reduce"]["operand_bytes"] == 32 * 4
        assert st["all-to-all"]["count"] == 1

    def test_skip_rules(self):
        assert skip_reason(get_config("granite-8b"), "long_500k")
        assert skip_reason(get_config("falcon-mamba-7b"), "long_500k") is None
        assert skip_reason(get_config("zamba2-7b"), "long_500k") is None
        for a in ARCH_IDS:
            assert skip_reason(get_config(a), "train_4k") is None


class TestDepthVariants:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_variants_preserve_family(self, arch):
        cfg = get_config(arch)
        c1, c2, u1, u2, uf = depth_variants(cfg)
        assert c1.family == cfg.family
        assert not c1.scan_layers and not c2.scan_layers
        assert c2.n_layers > c1.n_layers
        assert uf >= u2


class TestExtrapolationGroundTruth:
    def test_extrapolated_flops_match_unrolled_full(self):
        """Reduced qwen3 (6 layers): extrapolate from unrolled depths 1,2 →
        must match the fully unrolled 6-layer compile within 2%."""
        out = run_py("""
        import dataclasses, functools, jax
        from repro.configs import get_config
        from repro.launch import dryrun as dr
        from repro.launch.mesh import make_host_mesh

        cfg = get_config("qwen3-1.7b").reduced(
            n_layers=6, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
            vocab=512)
        mesh = make_host_mesh(2, 4)
        dr.SHAPES["tiny"] = dict(seq_len=64, global_batch=8, kind="train")

        def flops_of(c):
            fn, args, _ = dr.build_cell(c, "tiny", mesh, False)
            with mesh:
                comp = fn.lower(*args).compile()
            return dr.analyse_compiled(comp)["flops_per_device"]

        # ground truth: all 6 layers unrolled
        truth = flops_of(dataclasses.replace(cfg, scan_layers=False))
        c1, c2, u1, u2, uf = dr.depth_variants(cfg)
        f1, f2 = flops_of(c1), flops_of(c2)
        est = f2 + (f2 - f1) * (uf - u2) / (u2 - u1)
        rel = abs(est - truth) / truth
        print("REL", rel, "truth", truth, "est", est)
        """)
        rel = float(out.split("REL")[1].split()[0])
        assert rel < 0.02, f"extrapolation off by {rel:.1%}"

    def test_all_families_compile_sharded_tiny(self):
        """One tiny train cell per family on a (2,4) mesh — end-to-end
        through build_cell (sharding rules included)."""
        out = run_py("""
        import dataclasses, jax
        from repro.configs import get_config
        from repro.launch import dryrun as dr
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(2, 4)
        dr.SHAPES["tiny"] = dict(seq_len=64, global_batch=8, kind="train")
        dr.SHAPES["tinydec"] = dict(seq_len=64, global_batch=8,
                                    kind="decode")
        for arch in ("qwen3-1.7b", "kimi-k2-1t-a32b", "deepseek-v3-671b",
                     "falcon-mamba-7b", "zamba2-7b", "whisper-tiny",
                     "internvl2-76b"):
            cfg = get_config(arch).reduced()
            for shape in ("tiny", "tinydec"):
                fn, args, _ = dr.build_cell(cfg, shape, mesh, False)
                with mesh:
                    comp = fn.lower(*args).compile()
                a = dr.analyse_compiled(comp)
                assert a["flops_per_device"] > 0
            print("OK", arch)
        """)
        assert out.count("OK") == 7
