"""Device-resident engine tests (DESIGN.md §Engine): descriptor-table
lowering semantics, the fused QR/Barnes-Hut megakernels against their
sequential/rounds oracles, the single-dispatch runner (incl. whole-plan
fusion), host-dispatch accounting, and the ThreadedExecutor failure-path
regression."""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.apps import barneshut as bh
from repro.apps import qr
from repro.core import (FLAG_VIRTUAL, BatchSpec, QSched, ThreadedExecutor,
                        lower)


def _noop(tid, data):
    pass


def _identity_registry(types, arg_width=1):
    """Trivial device lowering: each task encodes to one row
    ``[type, tid]`` — enough to exercise the table layout."""
    return {tt: BatchSpec(
        run_one=_noop,
        encode=lambda tid, data, tt=tt: [(tt, tid)])
        for tt in types}


class TestDescriptorLowering:
    def _chain_sched(self):
        s = QSched()
        prev = None
        for i in range(3):
            t = s.addtask(type=i % 2, data=i, cost=1.0)
            if prev is not None:
                s.addunlock(prev, t)
            prev = t
        return s

    def test_table_layout_round_structure(self):
        s = self._chain_sched()
        plan = lower(s, 1, cache=False)
        tables = engine.lower_tables(plan, s, _identity_registry((0, 1)),
                                     arg_width=1, pad_type=9)
        assert tables.nr_rounds == plan.nr_rounds == 3
        assert tables.width == 1
        assert tables.nr_items == 3
        assert tables.lengths.tolist() == [1, 1, 1]
        assert tables.offsets.tolist() == [0, 1, 2, 3]
        # [etype, tid] rows in round order
        assert tables.desc[:, 0, :].tolist() == [[0, 0], [1, 1], [0, 2]]
        assert tables.tids[:, 0].tolist() == [0, 1, 2]

    def test_padding_rows_carry_pad_type(self):
        s = QSched()
        for i in range(5):           # one wide round
            s.addtask(type=0, data=i)
        t = s.addtask(type=0, data=5)
        s.addunlock(0, t)            # plus one narrow round
        plan = lower(s, 1, cache=False)
        tables = engine.lower_tables(plan, s, _identity_registry((0,)),
                                     arg_width=1, pad_type=7)
        assert tables.width == 5
        assert tables.lengths.tolist() == [5, 1]
        pad = tables.desc[1, 1:, 0]
        assert (pad == 7).all()
        assert (tables.tids[1, 1:] == -1).all()
        assert tables.stats["pad_rows"] == 4

    def test_row_order_mirrors_execute(self):
        """Rows within a round follow ascending task type then batch
        order — the host rounds-mode dispatch order."""
        s = QSched()
        for i in range(3):
            s.addtask(type=2, data=i)
        for i in range(2):
            s.addtask(type=1, data=i)
        plan = lower(s, 1, cache=False)
        tables = engine.lower_tables(plan, s, _identity_registry((1, 2)),
                                     arg_width=1, pad_type=9)
        assert tables.desc[0, :, 0].tolist() == [1, 1, 2, 2, 2]

    def test_virtual_tasks_encode_to_nothing(self):
        s = QSched()
        s.addtask(type=0, data="a")
        s.addtask(type=5, data="v", flags=FLAG_VIRTUAL)
        plan = lower(s, 1, cache=False)
        tables = engine.lower_tables(plan, s, _identity_registry((0,)),
                                     arg_width=1, pad_type=9)
        assert tables.nr_items == 1
        assert tables.round_tids(0) == [0]

    def test_task_may_expand_to_many_rows(self):
        s = QSched()
        s.addtask(type=0, data=3)
        reg = {0: BatchSpec(
            run_one=_noop,
            encode=lambda tid, data: [(0, k) for k in range(data)])}
        tables = engine.lower_tables(lower(s, 1, cache=False), s, reg,
                                     arg_width=1, pad_type=9)
        assert tables.nr_items == 3
        assert tables.tids[0].tolist() == [0, 0, 0]

    def test_missing_encode_raises(self):
        s = QSched()
        s.addtask(type=0)
        plan = lower(s, 1, cache=False)
        with pytest.raises(KeyError, match="no BatchSpec"):
            engine.lower_tables(plan, s, {}, arg_width=1, pad_type=9)
        with pytest.raises(KeyError, match="no engine "):
            engine.lower_tables(plan, s, {0: BatchSpec(run_one=_noop)},
                                arg_width=1, pad_type=9)

    def test_overwide_row_raises(self):
        s = QSched()
        s.addtask(type=0)
        reg = {0: BatchSpec(run_one=_noop,
                            encode=lambda tid, data: [(0, 1, 2, 3)])}
        with pytest.raises(ValueError, match="columns"):
            engine.lower_tables(lower(s, 1, cache=False), s, reg,
                                arg_width=1, pad_type=9)

    def test_structurally_different_sched_rejected(self):
        s1, _ = qr.make_qr_graph(4, 4)
        s2, _ = qr.make_qr_graph(5, 5)
        plan = lower(s1, 2)
        with pytest.raises(ValueError):
            engine.lower_tables(plan, s2, _identity_registry(range(4)),
                                arg_width=1, pad_type=9)


class TestHostDispatchCount:
    def test_counts_batches_and_singles(self):
        s = QSched()
        for i in range(4):
            s.addtask(type=0, data=i)    # one batched group → 1 dispatch
        for i in range(2):
            s.addtask(type=1, data=i)    # run_one only → 2 dispatches
        plan = lower(s, 1, cache=False)
        reg = {0: BatchSpec(run_one=_noop, run_batch=lambda t, d: None),
               1: BatchSpec(run_one=_noop)}
        assert engine.count_host_dispatches(plan, s, reg) == 3

    def test_qr_dispatch_reduction_floor(self):
        """Acceptance gate: the engine's single dispatch is ≥5× fewer than
        the per-round host path on a smoke-size QR plan."""
        a = jnp.zeros((128, 128), jnp.float32)
        tiles, mt, nt = qr._split_tiles(a, 32)
        s, _ = qr.make_qr_graph(mt, nt)
        plan = lower(s, 4)
        state = qr._TileState(tiles, "ref")
        host = engine.count_host_dispatches(plan, s, state.batch_registry())
        assert host >= 5 * engine.ENGINE_DISPATCHES_PER_PLAN


class TestQREngine:
    # NOTE: engine-vs-sequential equivalence is asserted (bitwise, across
    # every backend) by the matrix in tests/test_backends.py.
    def test_engine_rectangular_grid(self):
        """mt ≠ nt exercises the column-major tile-index arithmetic."""
        a = jnp.asarray(
            np.random.default_rng(1).standard_normal((160, 96)), jnp.float32)
        r1, _ = qr.run_qr(a, tile=32, mode="sequential", backend="pallas")
        r2, _ = qr.run_qr(a, tile=32, mode="engine")
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   atol=1e-5)

    def test_fused_plan_matches_per_round(self):
        """Whole-plan fusion (one megakernel launch) is row-order
        equivalent to the per-round fori_loop."""
        a = jnp.asarray(
            np.random.default_rng(2).standard_normal((96, 96)), jnp.float32)
        tiles, mt, nt = qr._split_tiles(a, 32)
        s, _ = qr.make_qr_graph(mt, nt)
        plan = lower(s, 4)
        state = qr._TileState(tiles, "pallas")
        tables = engine.lower_tables(
            plan, s, state.batch_registry(),
            arg_width=engine.QR_ARG_WIDTH, pad_type=engine.QR_NOOP)
        stack = jnp.stack([tiles[i, j]
                           for j in range(nt) for i in range(mt)])
        tmat = jnp.zeros_like(stack)
        # donate=False: the same buffers are deliberately reused across
        # the two calls (donation would delete them on TPU/GPU)
        out1, _ = engine.execute_plan(tables, engine.qr_round_fn(), (),
                                      (stack, tmat), donate=False)
        out2, _ = engine.execute_plan(tables, engine.qr_round_fn(), (),
                                      (stack, tmat), fuse_rounds=True,
                                      donate=False)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


class TestBHEngine:
    # NOTE: engine-vs-sequential/rounds acceleration equivalence is
    # asserted across every backend by the matrix in tests/test_backends.py.
    def test_engine_coms_match_sequential(self):
        """The in-kernel COM reduction (leaf blocks + one-hot child
        gathers) reproduces the host COM pass."""
        rng = np.random.default_rng(7)
        x, m = rng.random((400, 3)), rng.random(400) + 0.5
        _, st_seq, g = bh.solve(x, m, n_max=32, n_task=128, backend="ref",
                                mode="sequential")
        st_eng = bh.BHState(g, backend="ref")
        st_eng.run(mode="engine")
        for cid in range(len(g.tree.cells)):
            np.testing.assert_allclose(
                np.asarray(st_eng.com[cid]), np.asarray(st_seq.com[cid]),
                rtol=1e-5, atol=1e-6)


class TestThreadedExecutorFailure:
    """Regression (satellite): a worker exception must re-raise out of
    ``run`` promptly — before the abort flag, the surviving workers spun on
    the never-draining ``waiting`` counter and ``join`` hung forever, so
    failures passed silently (or rather, hung) instead of raising."""

    def _run_with_watchdog(self, exc_type, fn):
        box = {}

        def target():
            try:
                fn()
                box["outcome"] = None
            except BaseException as e:        # noqa: BLE001 - test capture
                box["outcome"] = e

        th = threading.Thread(target=target, daemon=True)
        th.start()
        th.join(timeout=30.0)
        assert not th.is_alive(), "ThreadedExecutor.run hung on failure"
        assert isinstance(box["outcome"], exc_type), box["outcome"]
        return box["outcome"]

    def test_worker_exception_reraises(self):
        s = QSched(nr_queues=2)
        for i in range(50):
            s.addtask(data=i)

        def fun(ttype, data):
            if data == 17:
                raise ValueError("task 17 exploded")

        ex = ThreadedExecutor(s, nr_threads=4)
        err = self._run_with_watchdog(ValueError, lambda: ex.run(fun))
        assert "task 17 exploded" in str(err)
        assert ex.errors and ex.errors[0] is err

    def test_exception_in_dependent_chain(self):
        """Failure mid-graph (dependents still waiting) must also unblock
        the pool."""
        s = QSched(nr_queues=2)
        prev = None
        for i in range(10):
            t = s.addtask(data=i)
            if prev is not None:
                s.addunlock(prev, t)
            prev = t

        def fun(ttype, data):
            if data == 3:
                raise RuntimeError("chain broke")

        ex = ThreadedExecutor(s, nr_threads=3)
        self._run_with_watchdog(RuntimeError, lambda: ex.run(fun))

    def test_errors_cleared_between_runs(self):
        s = QSched()
        for i in range(5):
            s.addtask(data=i)
        ex = ThreadedExecutor(s, nr_threads=2)
        with pytest.raises(ValueError):
            ex.run(lambda ty, d: (_ for _ in ()).throw(ValueError("x")))
        ex.run(lambda ty, d: None)       # second run succeeds cleanly
        assert ex.errors == []
