"""Device-resident engine tests (DESIGN.md §Engine): ragged CSR
descriptor-table lowering semantics, the write-coloring phase partition
(deterministic checks for all three real families — the randomized
property suite lives in test_engine_properties.py), the grid-walk
QR/Barnes-Hut megakernels against their sequential/rounds oracles, the
single-dispatch runner (incl. whole-plan fusion and per-item timing),
host-dispatch accounting, and the ThreadedExecutor failure-path
regression."""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.apps import barneshut as bh
from repro.apps import qr
from repro.core import (FLAG_VIRTUAL, BatchSpec, QSched, ThreadedExecutor,
                        lower)
from repro.pipeline import lower_pipeline_plan
from repro.pipeline.exec import _PipeRunner, dense_stage, mse_loss


def _noop(tid, data):
    pass


def _identity_registry(types, arg_width=1):
    """Trivial device lowering: each task encodes to one row
    ``[type, tid]`` — enough to exercise the table layout."""
    return {tt: BatchSpec(
        run_one=_noop,
        encode=lambda tid, data, tt=tt: [(tt, tid)])
        for tt in types}


class TestDescriptorLowering:
    def _chain_sched(self):
        s = QSched()
        prev = None
        for i in range(3):
            t = s.addtask(type=i % 2, data=i, cost=1.0)
            if prev is not None:
                s.addunlock(prev, t)
            prev = t
        return s

    def test_table_layout_round_structure(self):
        s = self._chain_sched()
        plan = lower(s, 1, cache=False)
        tables = engine.lower_tables(plan, s, _identity_registry((0, 1)),
                                     arg_width=1)
        assert tables.nr_rounds == plan.nr_rounds == 3
        assert tables.nr_items == 3
        assert tables.round_offsets.tolist() == [0, 1, 2, 3]
        assert tables.round_lengths.tolist() == [1, 1, 1]
        # [etype, tid] rows in flat round order
        assert tables.desc.tolist() == [[0, 0], [1, 1], [0, 2]]
        assert tables.tids.tolist() == [0, 1, 2]
        # no row_access: one phase per non-empty round
        assert tables.nr_phases == 3
        assert tables.phase_offsets.tolist() == [0, 1, 2, 3]
        for r in range(3):
            assert tables.round_phases(r).tolist() == [r, r + 1]

    def test_no_padding_anywhere(self):
        """Ragged CSR: a wide and a narrow round share the flat row array
        with zero pad rows (the dense layout would have padded the narrow
        round to width 5)."""
        s = QSched()
        for i in range(5):           # one wide round
            s.addtask(type=0, data=i)
        t = s.addtask(type=0, data=5)
        s.addunlock(0, t)            # plus one narrow round
        plan = lower(s, 1, cache=False)
        tables = engine.lower_tables(plan, s, _identity_registry((0,)),
                                     arg_width=1)
        assert tables.round_lengths.tolist() == [5, 1]
        assert tables.nr_items == 6
        assert tables.desc.shape == (6, 2)
        assert tables.stats["pad_rows"] == 0
        assert tables.stats["pad_fraction"] == 0.0
        assert tables.stats["width"] == 5
        assert tables.stats["padded_rows"] == 10    # what the old slab did

    def test_empty_round_zero_csr_length(self):
        """An all-virtual round lowers to a zero-length CSR slice and zero
        phases — not a synthetic no-op row (satellite regression: the old
        dense layout emitted a full pad round)."""
        s = QSched()
        t0 = s.addtask(type=0, data=0)
        tv = s.addtask(type=7, data="v", flags=FLAG_VIRTUAL)
        t2 = s.addtask(type=0, data=2)
        s.addunlock(t0, tv)
        s.addunlock(tv, t2)
        plan = lower(s, 1, cache=False)
        assert plan.nr_rounds == 3
        tables = engine.lower_tables(plan, s, _identity_registry((0,)),
                                     arg_width=1)
        assert tables.nr_rounds == 3
        assert tables.round_lengths.tolist() == [1, 0, 1]
        assert tables.nr_items == 2
        assert tables.nr_phases == 2          # the empty round has none
        assert tables.round_phases(1).tolist() == [1]
        assert tables.stats["pad_fraction"] == 0.0

    def test_all_virtual_plan_is_empty_table(self):
        s = QSched()
        s.addtask(type=3, data="v", flags=FLAG_VIRTUAL)
        tables = engine.lower_tables(lower(s, 1, cache=False), s, {},
                                     arg_width=1)
        assert tables.nr_rounds == 1
        assert tables.nr_items == 0
        assert tables.nr_phases == 0
        assert tables.desc.shape == (0, 2)

    def test_row_order_mirrors_execute(self):
        """Rows within a round follow ascending task type then batch
        order — the host rounds-mode dispatch order."""
        s = QSched()
        for i in range(3):
            s.addtask(type=2, data=i)
        for i in range(2):
            s.addtask(type=1, data=i)
        plan = lower(s, 1, cache=False)
        tables = engine.lower_tables(plan, s, _identity_registry((1, 2)),
                                     arg_width=1)
        assert tables.desc[:, 0].tolist() == [1, 1, 2, 2, 2]

    def test_virtual_tasks_encode_to_nothing(self):
        s = QSched()
        s.addtask(type=0, data="a")
        s.addtask(type=5, data="v", flags=FLAG_VIRTUAL)
        plan = lower(s, 1, cache=False)
        tables = engine.lower_tables(plan, s, _identity_registry((0,)),
                                     arg_width=1)
        assert tables.nr_items == 1
        assert tables.round_tids(0) == [0]

    def test_task_may_expand_to_many_rows(self):
        s = QSched()
        s.addtask(type=0, data=3)
        reg = {0: BatchSpec(
            run_one=_noop,
            encode=lambda tid, data: [(0, k) for k in range(data)])}
        tables = engine.lower_tables(lower(s, 1, cache=False), s, reg,
                                     arg_width=1)
        assert tables.nr_items == 3
        assert tables.tids.tolist() == [0, 0, 0]

    def test_missing_encode_raises(self):
        s = QSched()
        s.addtask(type=0)
        plan = lower(s, 1, cache=False)
        with pytest.raises(KeyError, match="no BatchSpec"):
            engine.lower_tables(plan, s, {}, arg_width=1)
        with pytest.raises(KeyError, match="no engine "):
            engine.lower_tables(plan, s, {0: BatchSpec(run_one=_noop)},
                                arg_width=1)

    def test_overwide_row_raises(self):
        s = QSched()
        s.addtask(type=0)
        reg = {0: BatchSpec(run_one=_noop,
                            encode=lambda tid, data: [(0, 1, 2, 3)])}
        with pytest.raises(ValueError, match="columns"):
            engine.lower_tables(lower(s, 1, cache=False), s, reg,
                                arg_width=1)

    def test_structurally_different_sched_rejected(self):
        s1, _ = qr.make_qr_graph(4, 4)
        s2, _ = qr.make_qr_graph(5, 5)
        plan = lower(s1, 2)
        with pytest.raises(ValueError):
            engine.lower_tables(plan, s2, _identity_registry(range(4)),
                                arg_width=1)


class TestWriteColoring:
    """The phase partition (core.plan.color_phases through
    descriptors.lower_tables): phases are contiguous, cover each round
    exactly, no two items of a phase touch a common state row, and items
    sharing a destination keep their order (so accumulation bit patterns
    match the sequential walk)."""

    def _colliding_table(self):
        """One round of 4 independent tasks; tasks 0/2 write key 7, tasks
        1/3 write keys 1/3 — the coloring must split 0 and 2."""
        s = QSched()
        for i, key in enumerate((7, 1, 7, 3)):
            s.addtask(type=0, data=key)
        reg = {0: BatchSpec(run_one=_noop,
                            encode=lambda tid, data: [(0, data)])}
        tables = engine.lower_tables(
            lower(s, 1, cache=False), s, reg, arg_width=1,
            row_access=lambda row: ((), (("k", row[1]),)))
        return tables

    def test_same_destination_rows_split_phases(self):
        tables = self._colliding_table()
        assert tables.nr_rounds == 1
        assert tables.nr_phases == 2
        bounds = tables.round_phases(0).tolist()
        phases = [set(map(tuple, tables.desc[b0:b1].tolist()))
                  for b0, b1 in zip(bounds, bounds[1:])]
        for ph in phases:
            keys = [r[1] for r in ph]
            assert len(keys) == len(set(keys)), "write key repeated in phase"
        # per-destination order: the first key-7 row precedes the second
        key7 = [q for q in range(tables.nr_items)
                if tables.desc[q, 1] == 7]
        assert tables.tids[key7].tolist() == [0, 2]

    @staticmethod
    def assert_phases_safe(tables, row_access):
        """No two items of one sub-phase read or write a common state
        row — the invariant that makes the block grid walk of a phase
        order-independent (and parallelizable)."""
        assert tables.phase_offsets[0] == 0
        assert tables.phase_offsets[-1] == tables.nr_items
        assert (np.diff(tables.phase_offsets) > 0).all()
        for r in range(tables.nr_rounds):
            bounds = tables.round_phases(r).tolist()
            assert bounds[0] == tables.round_offsets[r]
            assert bounds[-1] == tables.round_offsets[r + 1]
            for b0, b1 in zip(bounds, bounds[1:]):
                reads, writes = set(), set()
                for q in range(b0, b1):
                    row = tuple(int(v) for v in tables.desc[q])
                    rr, ww = row_access(row)
                    rr, ww = set(rr), set(ww)
                    assert not (ww & writes), \
                        f"round {r}: write/write overlap in one phase"
                    assert not (ww & reads) and not (rr & writes), \
                        f"round {r}: read/write overlap in one phase"
                    reads |= rr
                    writes |= ww

    def test_qr_family_phases_safe(self):
        s, _ = qr.make_qr_graph(5, 5)
        plan = lower(s, 4)
        tiles = {(i, j): jnp.zeros((4, 4), jnp.float32)
                 for i in range(5) for j in range(5)}
        tables = engine.lower_tables(
            plan, s, qr._TileState(tiles, "ref").batch_registry(),
            arg_width=engine.QR_ARG_WIDTH, row_access=engine.qr_row_access)
        self.assert_phases_safe(tables, engine.qr_row_access)

    def test_bh_family_phases_safe(self):
        rng = np.random.default_rng(5)
        x, m = rng.random((600, 3)), rng.random(600) + 0.5
        tree = bh.Octree(x, m, n_max=32)
        g = bh.build_graph(tree, n_task=128, nr_queues=2)
        st = bh.BHState(g, backend="ref")
        tables = engine.lower_tables(
            lower(g.sched, 2), g.sched, st.batch_registry(),
            arg_width=engine.BH_ARG_WIDTH, row_access=engine.bh_row_access)
        # BH tasks expand into many same-destination accumulation rows —
        # the coloring must actually split (this is the interesting case)
        assert tables.nr_phases > tables.nr_rounds
        self.assert_phases_safe(tables, engine.bh_row_access)

    def test_pipeline_family_phases_safe(self):
        S, M, Bt, D = 3, 5, 2, 4
        params = [{"w": jnp.zeros((D, D)), "b": jnp.zeros((D,))}
                  for _ in range(S)]
        micro = [{"x": jnp.zeros((Bt, D)), "y": jnp.zeros((Bt, D))}
                 for _ in range(M)]
        runner = _PipeRunner([dense_stage] * S, mse_loss, params, micro)
        sched, _, plan = lower_pipeline_plan(S, M, per_stage_window=True)
        tables = engine.lower_tables(
            plan, sched, runner.registry(),
            arg_width=engine.PIPE_ARG_WIDTH,
            row_access=engine.pipe_row_access)
        self.assert_phases_safe(tables, engine.pipe_row_access)


class TestHostDispatchCount:
    def test_counts_batches_and_singles(self):
        s = QSched()
        for i in range(4):
            s.addtask(type=0, data=i)    # one batched group → 1 dispatch
        for i in range(2):
            s.addtask(type=1, data=i)    # run_one only → 2 dispatches
        plan = lower(s, 1, cache=False)
        reg = {0: BatchSpec(run_one=_noop, run_batch=lambda t, d: None),
               1: BatchSpec(run_one=_noop)}
        assert engine.count_host_dispatches(plan, s, reg) == 3

    def test_qr_dispatch_reduction_floor(self):
        """Acceptance gate: the engine's single dispatch is ≥5× fewer than
        the per-round host path on a smoke-size QR plan."""
        a = jnp.zeros((128, 128), jnp.float32)
        tiles, mt, nt = qr._split_tiles(a, 32)
        s, _ = qr.make_qr_graph(mt, nt)
        plan = lower(s, 4)
        state = qr._TileState(tiles, "ref")
        host = engine.count_host_dispatches(plan, s, state.batch_registry())
        assert host >= 5 * engine.ENGINE_DISPATCHES_PER_PLAN


class TestQREngine:
    # NOTE: engine-vs-sequential equivalence is asserted (bitwise, across
    # every backend) by the matrix in tests/test_backends.py.
    def test_engine_rectangular_grid(self):
        """mt ≠ nt exercises the column-major tile-index arithmetic."""
        a = jnp.asarray(
            np.random.default_rng(1).standard_normal((160, 96)), jnp.float32)
        r1, _ = qr.run_qr(a, tile=32, mode="sequential", backend="pallas")
        r2, _ = qr.run_qr(a, tile=32, mode="engine")
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   atol=1e-5)

    def test_fused_plan_matches_per_round(self):
        """Whole-plan fusion (one megakernel launch walking every phase)
        is bitwise equivalent to the per-round launch loop."""
        a = jnp.asarray(
            np.random.default_rng(2).standard_normal((96, 96)), jnp.float32)
        tiles, mt, nt = qr._split_tiles(a, 32)
        s, _ = qr.make_qr_graph(mt, nt)
        plan = lower(s, 4)
        state = qr._TileState(tiles, "pallas")
        tables = engine.lower_tables(
            plan, s, state.batch_registry(),
            arg_width=engine.QR_ARG_WIDTH, row_access=engine.qr_row_access)
        stack = jnp.stack([tiles[i, j]
                           for j in range(nt) for i in range(mt)])
        tmat = jnp.zeros_like(stack)
        # donate=False: the same buffers are deliberately reused across
        # the two calls (donation would delete them on TPU/GPU)
        out1, _ = engine.execute_plan(tables, engine.qr_round_fn(), (),
                                      (stack, tmat), donate=False)
        out2, _ = engine.execute_plan(tables, engine.qr_round_fn(), (),
                                      (stack, tmat), fuse_rounds=True,
                                      donate=False)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))

    def test_block_size_does_not_change_result(self):
        """The block grid is a pure execution-shape choice: 1-item blocks
        and whole-phase blocks produce bitwise-identical tiles."""
        a = jnp.asarray(
            np.random.default_rng(3).standard_normal((96, 96)), jnp.float32)
        tiles, mt, nt = qr._split_tiles(a, 32)
        s, _ = qr.make_qr_graph(mt, nt)
        plan = lower(s, 4)
        state = qr._TileState(tiles, "pallas")
        tables = engine.lower_tables(
            plan, s, state.batch_registry(),
            arg_width=engine.QR_ARG_WIDTH, row_access=engine.qr_row_access)
        stack = jnp.stack([tiles[i, j]
                           for j in range(nt) for i in range(mt)])
        outs = []
        for bi in (1, 64):
            fn = engine.qr_round_fn(block_items=bi)
            out, _ = engine.execute_plan(tables, fn, (),
                                         (stack, jnp.zeros_like(stack)),
                                         fuse_rounds=True, donate=False)
            outs.append(np.asarray(out))
        np.testing.assert_array_equal(outs[0], outs[1])


class TestBHEngine:
    # NOTE: engine-vs-sequential/rounds acceleration equivalence is
    # asserted across every backend by the matrix in tests/test_backends.py.
    def test_engine_coms_match_sequential(self):
        """The in-kernel COM reduction (leaf blocks + one-hot child
        gathers) reproduces the host COM pass."""
        rng = np.random.default_rng(7)
        x, m = rng.random((400, 3)), rng.random(400) + 0.5
        _, st_seq, g = bh.solve(x, m, n_max=32, n_task=128, backend="ref",
                                mode="sequential")
        st_eng = bh.BHState(g, backend="ref")
        st_eng.run(mode="engine")
        for cid in range(len(g.tree.cells)):
            np.testing.assert_allclose(
                np.asarray(st_eng.com[cid]), np.asarray(st_seq.com[cid]),
                rtol=1e-5, atol=1e-6)


class TestThreadedExecutorFailure:
    """Regression (satellite): a worker exception must re-raise out of
    ``run`` promptly — before the abort flag, the surviving workers spun on
    the never-draining ``waiting`` counter and ``join`` hung forever, so
    failures passed silently (or rather, hung) instead of raising."""

    def _run_with_watchdog(self, exc_type, fn):
        box = {}

        def target():
            try:
                fn()
                box["outcome"] = None
            except BaseException as e:        # noqa: BLE001 - test capture
                box["outcome"] = e

        th = threading.Thread(target=target, daemon=True)
        th.start()
        th.join(timeout=30.0)
        assert not th.is_alive(), "ThreadedExecutor.run hung on failure"
        assert isinstance(box["outcome"], exc_type), box["outcome"]
        return box["outcome"]

    def test_worker_exception_reraises(self):
        s = QSched(nr_queues=2)
        for i in range(50):
            s.addtask(data=i)

        def fun(ttype, data):
            if data == 17:
                raise ValueError("task 17 exploded")

        ex = ThreadedExecutor(s, nr_threads=4)
        err = self._run_with_watchdog(ValueError, lambda: ex.run(fun))
        assert "task 17 exploded" in str(err)
        assert ex.errors and ex.errors[0] is err

    def test_exception_in_dependent_chain(self):
        """Failure mid-graph (dependents still waiting) must also unblock
        the pool."""
        s = QSched(nr_queues=2)
        prev = None
        for i in range(10):
            t = s.addtask(data=i)
            if prev is not None:
                s.addunlock(prev, t)
            prev = t

        def fun(ttype, data):
            if data == 3:
                raise RuntimeError("chain broke")

        ex = ThreadedExecutor(s, nr_threads=3)
        self._run_with_watchdog(RuntimeError, lambda: ex.run(fun))

    def test_errors_cleared_between_runs(self):
        s = QSched()
        for i in range(5):
            s.addtask(data=i)
        ex = ThreadedExecutor(s, nr_threads=2)
        with pytest.raises(ValueError):
            ex.run(lambda ty, d: (_ for _ in ()).throw(ValueError("x")))
        ex.run(lambda ty, d: None)       # second run succeeds cleanly
        assert ex.errors == []
