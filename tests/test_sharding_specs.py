"""PartitionSpec conventions of repro.dist.sharding (DESIGN.md §Distributed)
on the single-pod production mesh: one dense, one MoE, and one SSM config.

Uses an AbstractMesh with the production axis sizes (16×16 = 256 chips) —
spec derivation is a pure function of mesh *shape*, so no devices are
needed; the dry-run subprocess tests cover real lowering."""

import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data import batch_specs
from repro.dist.act_sharding import activation_sharding, constrain
from repro.dist.sharding import (batch_pspecs, cache_pspecs, opt_pspecs,
                                 param_pspecs)
from repro.models import lm, serving
from repro.optim import make_optimizer

def _abstract_mesh():
    """Spec derivation only reads ``mesh.shape``; prefer AbstractMesh
    (constructor differs across jax versions), else a bare stand-in."""
    try:
        from jax.sharding import AbstractMesh
    except ImportError:
        AbstractMesh = None
    if AbstractMesh is not None:
        for args in ((((("data", 16), ("model", 16))),),   # jax 0.4.x
                     ((16, 16), ("data", "model"))):       # jax ≥ 0.5
            try:
                return AbstractMesh(*args)
            except TypeError:
                continue

    class _MeshShape:
        shape = {"data": 16, "model": 16}

    return _MeshShape()


MESH = _abstract_mesh()


def _param_shapes(arch):
    cfg = get_config(arch)
    return cfg, jax.eval_shape(
        functools.partial(lm.init_params, jax.random.PRNGKey(0), cfg))


class TestParamSpecs:
    def test_dense_qwen3(self):
        _, shapes = _param_shapes("qwen3-1.7b")
        ps = param_pspecs(shapes, MESH)
        # stacked weight (L, d, H*hd): TP on the output dim, FSDP on d
        assert ps["layers"]["attn"]["wq"] == P(None, "data", "model")
        assert ps["layers"]["mlp"]["w_down"] == P(None, "data", "model")
        assert ps["embed"]["tok"] == P("data", "model")
        assert ps["final_norm"]["scale"] == P("model")
        # L=28 does not divide data=16 → stack dim of rank-2 leaves replicated
        assert ps["layers"]["attn_norm"]["scale"] == P(None, "model")

    def test_moe_kimi(self):
        _, shapes = _param_shapes("kimi-k2-1t-a32b")
        ps = param_pspecs(shapes, MESH)
        # expert-stacked (L_moe, E, d, d_ff): experts replicated, d FSDP
        assert ps["moe_layers"]["moe"]["w_gate"] == P(None, None, "data",
                                                      "model")
        assert ps["moe_layers"]["moe"]["router"] == P(None, "data", "model")

    def test_ssm_falcon_mamba(self):
        _, shapes = _param_shapes("falcon-mamba-7b")
        ps = param_pspecs(shapes, MESH)
        assert ps["layers"]["mamba"]["in_proj"] == P(None, "data", "model")
        # conv taps (L, dI, K=4): K indivisible → replicated, dI FSDP
        assert ps["layers"]["mamba"]["conv_w"] == P(None, "data", None)
        assert ps["layers"]["mamba"]["a_log"] == P(None, "data", "model")

    @pytest.mark.parametrize("arch", ["qwen3-1.7b", "kimi-k2-1t-a32b",
                                      "falcon-mamba-7b"])
    def test_specs_well_formed(self, arch):
        """Axes always divide their dim; rank≥3 stack dims never sharded."""
        _, shapes = _param_shapes(arch)
        for leaf, spec in zip(jax.tree.leaves(shapes),
                              jax.tree.leaves(param_pspecs(shapes, MESH))):
            entries = tuple(spec) + (None,) * (leaf.ndim - len(spec))
            for dim, entry in zip(leaf.shape, entries):
                if entry is not None:
                    assert dim % MESH.shape[entry] == 0, (leaf.shape, spec)
            if leaf.ndim >= 3:
                assert entries[0] is None, (leaf.shape, spec)


class TestOptBatchCacheSpecs:
    def test_adamw_moments_inherit_param_specs(self):
        cfg, shapes = _param_shapes("qwen3-1.7b")
        opt_init, _ = make_optimizer("adamw", 1e-3)
        opt_shapes = jax.eval_shape(opt_init, shapes)
        ps = param_pspecs(shapes, MESH)
        os_ = opt_pspecs(ps, opt_shapes, MESH)
        assert os_.step == P()
        assert jax.tree.all(jax.tree.map(lambda a, b: a == b,
                                         os_.inner["m"], ps))
        assert jax.tree.all(jax.tree.map(lambda a, b: a == b,
                                         os_.inner["v"], ps))

    def test_batch_specs_dp_or_replicated(self):
        cfg = get_config("qwen3-1.7b")
        bs = batch_pspecs(batch_specs(cfg, 4096, 256, "train"), MESH)
        assert bs["tokens"] == P("data", None)
        # global_batch=1 (long_500k) does not divide dp=16 → replicated
        bs1 = batch_pspecs(batch_specs(cfg, 4096, 1, "train"), MESH)
        assert bs1["tokens"] == P(None, None)

    def test_kv_cache_specs(self):
        cfg = get_config("qwen3-1.7b")
        shapes = jax.eval_shape(
            functools.partial(serving.init_cache, cfg, 32, 1024))
        cs = cache_pspecs(shapes, cfg, MESH)
        # (L, B, S, Hkv=8, hd=128): batch → dp; Hkv indivisible → hd TP
        assert cs["k"] == P(None, "data", None, None, "model")
        assert cs["v"] == cs["k"]

    def test_ssm_cache_specs(self):
        cfg = get_config("falcon-mamba-7b")
        shapes = jax.eval_shape(
            functools.partial(serving.init_cache, cfg, 32, 1024))
        cs = cache_pspecs(shapes, cfg, MESH)
        assert cs["conv"] == P(None, "data", None, "model")   # dI channels
        assert cs["h"] == P(None, "data", None, "model")      # N=16 state


class TestConstrain:
    def test_noop_outside_context(self):
        x = jnp.ones((4, 4))
        assert constrain(x, "dp", "tp") is x

    def test_applies_inside_mesh_and_context(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with mesh, activation_sharding("data", "model"):
            fn = jax.jit(lambda x: constrain(x, "dp", "tp") * 2.0)
            lowered = fn.lower(jnp.ones((4, 4)))
            y = fn(jnp.ones((4, 4)))
        assert bool((y == 2.0).all())
        # the constraint must actually land in the lowered module — guards
        # against _ambient_mesh silently degrading constrain to a no-op
        txt = lowered.as_text().lower()
        assert "sharding" in txt, "no sharding constraint in lowered HLO"

    def test_indivisible_dims_are_dropped_not_fatal(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with mesh, activation_sharding(("pod", "data"), "model"):
            # "pod" absent from this mesh and 3 indivisible by nothing —
            # both entries must degrade to replication, not raise
            y = jax.jit(lambda x: constrain(x, "dp", None, "tp"))(
                jnp.ones((3, 5, 7)))
        assert y.shape == (3, 5, 7)
