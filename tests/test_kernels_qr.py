"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/dtypes + hypothesis property tests (paper §4.1 kernels)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels.qr_tile import kernel, ref

SIZES = [4, 8, 16, 32, 64]
DTYPES = [jnp.float32]


def rand(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       dtype=dtype)


@pytest.mark.parametrize("b", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_geqrf_matches_ref(b, dtype):
    a = rand((b, b), b, dtype)
    rv_k, tau_k, t_k = kernel.geqrf(a, interpret=True)
    rv_r, tau_r, t_r = ref.geqrf_ref(a)
    assert_allclose(np.asarray(rv_k), np.asarray(rv_r), atol=2e-5, rtol=1e-4)
    assert_allclose(np.asarray(tau_k), np.asarray(tau_r), atol=2e-5, rtol=1e-4)
    assert_allclose(np.asarray(t_k), np.asarray(t_r), atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("b", SIZES)
def test_geqrf_reconstructs(b):
    """Q @ R == A and Q orthonormal (factorization-level invariant)."""
    a = rand((b, b), 7 * b)
    rv, tau, t = kernel.geqrf(a, interpret=True)
    r = np.triu(np.asarray(rv))
    v = np.tril(np.asarray(rv), -1) + np.eye(b)
    q = np.eye(b) - v @ np.asarray(t) @ v.T
    assert_allclose(q @ r, np.asarray(a), atol=5e-4)
    assert_allclose(q.T @ q, np.eye(b), atol=5e-4)


@pytest.mark.parametrize("b", SIZES)
def test_tsqrf_matches_ref(b):
    r0 = jnp.triu(rand((b, b), b + 1))
    a = rand((b, b), b + 2)
    outs_k = kernel.tsqrf(r0, a, interpret=True)
    outs_r = ref.tsqrf_ref(r0, a)
    for g, w in zip(outs_k, outs_r):
        assert_allclose(np.asarray(g), np.asarray(w), atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("b", SIZES)
def test_tsqrf_reconstructs(b):
    r0 = jnp.triu(rand((b, b), 3 * b))
    a = rand((b, b), 3 * b + 1)
    r1, v2, tau, t = kernel.tsqrf(r0, a, interpret=True)
    vfull = np.vstack([np.eye(b), np.asarray(v2)])
    q = np.eye(2 * b) - vfull @ np.asarray(t) @ vfull.T
    rec = q @ np.vstack([np.asarray(r1), np.zeros((b, b), np.float32)])
    assert_allclose(rec, np.vstack([np.asarray(r0), np.asarray(a)]), atol=5e-4)


@pytest.mark.parametrize("b", SIZES)
def test_apply_qt_matches_ref(b):
    a = rand((b, b), b + 3)
    rv, tau, t = ref.geqrf_ref(a)
    c = rand((b, b), b + 4)
    got = kernel.apply_qt(rv, t, c, interpret=True)
    want = ref.apply_qt_ref(rv, t, c)
    assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("b", SIZES)
def test_apply_tsqt_matches_ref(b):
    r0 = jnp.triu(rand((b, b), b + 5))
    a = rand((b, b), b + 6)
    _, v2, _, t = ref.tsqrf_ref(r0, a)
    c1, c2 = rand((b, b), b + 7), rand((b, b), b + 8)
    g1, g2 = kernel.apply_tsqt(v2, t, c1, c2, interpret=True)
    w1, w2 = ref.apply_tsqt_ref(v2, t, c1, c2)
    assert_allclose(np.asarray(g1), np.asarray(w1), atol=1e-5)
    assert_allclose(np.asarray(g2), np.asarray(w2), atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(b=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**16),
       scale=st.floats(0.01, 100.0))
def test_property_geqrf_cholesky_identity(b, seed, scale):
    """R^T R == A^T A for any well-conditioned input and scale (QR identity,
    checked directly on the Pallas kernel)."""
    a = rand((b, b), seed) * scale
    rv, tau, t = kernel.geqrf(a, interpret=True)
    r = np.triu(np.asarray(rv))
    lhs, rhs = r.T @ r, np.asarray(a).T @ np.asarray(a)
    norm = max(np.abs(rhs).max(), 1e-6)
    assert np.abs(lhs - rhs).max() / norm < 5e-5


@settings(max_examples=12, deadline=None)
@given(b=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
def test_property_apply_preserves_norms(b, seed):
    """Q^T is orthogonal: column norms of C are preserved by apply_qt."""
    a = rand((b, b), seed)
    rv, tau, t = kernel.geqrf(a, interpret=True)
    c = rand((b, b), seed + 1)
    got = kernel.apply_qt(rv, t, c, interpret=True)
    assert_allclose(np.linalg.norm(np.asarray(got), axis=0),
                    np.linalg.norm(np.asarray(c), axis=0), rtol=1e-4)
