"""Robustness-layer conformance tests (DESIGN.md §Robustness).

Every failure path of the serving tier is driven deterministically by
the chaos harness (`repro.serve.faults`) and pinned against the
fault-free run of the same workload:

* transient NaN faults recover via the in-tick retry, bitwise;
* sticky NaN faults force preemption + re-admission and *still* recover
  bitwise (greedy prefill of prompt + generated reproduces the evicted
  continuation exactly);
* admission failures roll back with page conservation and retry;
* deadline expiry and cancellation reach terminal states with the
  prefix property (what was generated matches the fault-free stream);
* requests untouched by any fault are bitwise-identical to the
  fault-free run (the chaos-blast-radius contract);
* the degrade ladder walks down on faulted ticks and promotes back
  after the exponential-backoff cooldown.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm, serving
from repro.serve import (FaultEvent, FaultPlan, GenerateService, QueueFull,
                         ServiceStalled, open_loop_trace)
from repro.serve.traffic import replay

MAX_SEQ = 24


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _reference_tokens(params, cfg, prompt, n_new):
    """Sequential single-request greedy reference (as in test_serve)."""
    logits, cache, pos = serving.prefill(params, cfg, prompt[None])
    cache = {k: jnp.pad(v, [(0, 0), (0, 0), (0, MAX_SEQ - v.shape[2])]
                        + [(0, 0)] * (v.ndim - 3))
             for k, v in cache.items()}
    toks = [int(np.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, cache = serving.decode_step(
            params, cfg, cache, jnp.asarray([[toks[-1]]], jnp.int32), pos)
        toks.append(int(np.argmax(logits[0])))
        pos = pos + 1
    return toks


def _prompts(cfg, plens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=pl, dtype=np.int32)
            for pl in plens]


def _drained(svc):
    """Terminal-state + conservation postconditions every scenario ends
    with."""
    assert not svc._active and not svc._queue
    assert svc.pool.allocated == 0
    svc.pool.check_invariants()


def test_transient_nan_retries_and_recovers(dense):
    """sticky=1: the guard trips, the gather retry recomputes the tick
    cleanly, the stream is bitwise-unharmed and nothing is preempted."""
    params, cfg = dense
    (prompt,) = _prompts(cfg, [5])
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4,
                          faults=FaultPlan([FaultEvent(2, "nan_decode",
                                                       sticky=1)]))
    h = svc.submit(prompt, 6)
    svc.run_until_complete()
    assert h.status == "done" and h.generated == _reference_tokens(
        params, cfg, prompt, 6)
    assert svc.stats["retries"] == 1
    assert svc.stats["preemptions"] == 0
    assert svc.stats["faults_injected"] == 1
    assert h.rid in svc.retried_rids and h.rid not in svc.faulted_rids
    _drained(svc)


def test_sticky_nan_preempts_and_readmits_bitwise(dense):
    """sticky=3 poisons the retry too: the victim is preempted, its
    pages reclaimed, and re-admission (prefill of prompt + generated)
    continues the greedy stream bitwise."""
    params, cfg = dense
    (prompt,) = _prompts(cfg, [5])
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4,
                          faults=FaultPlan([FaultEvent(2, "nan_decode",
                                                       sticky=3)]))
    h = svc.submit(prompt, 6)
    svc.run_until_complete()
    assert h.status == "done" and h.preemptions == 1
    assert h.generated == _reference_tokens(params, cfg, prompt, 6)
    assert svc.stats["preemptions"] == 1
    assert svc.stats["retries"] >= 1
    assert h.rid in svc.faulted_rids
    _drained(svc)


def test_admission_failure_rolls_back_and_retries(dense):
    """An injected AdmissionConflict after pages/slots were assigned must
    roll back completely (conservation asserted inside the service) and
    the batch must admit cleanly on the next tick."""
    params, cfg = dense
    prompts = _prompts(cfg, [5, 5])
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4,
                          faults=FaultPlan([FaultEvent(0, "admission_fail")]))
    hs = [svc.submit(p, 3) for p in prompts]
    svc.run_until_complete()
    for h, p in zip(hs, prompts):
        assert h.status == "done"
        assert h.generated == _reference_tokens(params, cfg, p, 3)
    assert svc.stats["retries"] == 2          # both rolled-back requests
    assert svc.stats["admitted"] == 2
    _drained(svc)


def test_drop_prefill_respecializes_midstream(dense):
    """Dropping the prefill entry-point cache mid-stream forces cold
    re-specialization on the next admission; streams are unaffected."""
    params, cfg = dense
    prompts = _prompts(cfg, [5, 5])
    svc = GenerateService(params, cfg, max_batch=1, max_seq=MAX_SEQ,
                          page_size=4,
                          faults=FaultPlan([FaultEvent(1, "drop_prefill")]))
    hs = [svc.submit(p, 3) for p in prompts]   # max_batch=1: B admits later
    svc.run_until_complete()
    for h, p in zip(hs, prompts):
        assert h.status == "done"
        assert h.generated == _reference_tokens(params, cfg, p, 3)
    assert (5, 1) in svc._prefill_fns          # rebuilt after the drop
    _drained(svc)


def test_stall_expires_deadlines_prefix_property(dense):
    """A stall jumping the virtual clock expires the deadlined request
    (active victim preempted terminally; what it generated is a prefix of
    the fault-free stream) while the undeadlined request is untouched."""
    params, cfg = dense
    prompts = _prompts(cfg, [5, 7])
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4,
                          faults=FaultPlan([FaultEvent(2, "stall",
                                                       skew_s=7200.0)]))
    victim = svc.submit(prompts[0], 8, deadline_ms=3600_000.0)
    other = svc.submit(prompts[1], 8)
    svc.run_until_complete()
    assert victim.status == "deadline_exceeded" and victim.done
    ref = _reference_tokens(params, cfg, prompts[0], 8)
    assert victim.generated == ref[:len(victim.generated)]
    assert len(victim.generated) < 8
    assert other.status == "done"
    assert other.generated == _reference_tokens(params, cfg, prompts[1], 8)
    assert svc.stats["deadline_exceeded"] == 1
    assert victim.rid in svc.faulted_rids and other.rid not in svc.faulted_rids
    _drained(svc)


def test_queued_deadline_expires_without_tokens(dense):
    """A request whose deadline passes while still queued retires
    terminally with zero tokens and never takes pages."""
    params, cfg = dense
    prompts = _prompts(cfg, [5, 5])
    svc = GenerateService(params, cfg, max_batch=1, max_seq=MAX_SEQ,
                          page_size=4,
                          faults=FaultPlan([FaultEvent(1, "stall",
                                                       skew_s=7200.0)]))
    first = svc.submit(prompts[0], 6)
    queued = svc.submit(prompts[1], 6, deadline_ms=3600_000.0)
    svc.run_until_complete()
    assert first.status == "done" and len(first.generated) == 6
    assert queued.status == "deadline_exceeded" and queued.generated == []
    assert queued.t_done > 0 and queued.latency_s > 0
    _drained(svc)


def test_cancel_active_and_queued(dense):
    """cancel() preempts an active victim (pages reclaimed) and removes a
    queued one; the surviving request's stream is bitwise-unaffected.
    Unknown / already-terminal rids return False."""
    params, cfg = dense
    prompts = _prompts(cfg, [5, 5, 5])
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4)
    keeper = svc.submit(prompts[0], 6)
    active_victim = svc.submit(prompts[1], 6)
    svc.step()
    queued_victim = svc.submit(prompts[2], 6)   # both slots taken: queued
    assert svc.cancel(active_victim.rid)
    assert svc.cancel(queued_victim.rid)
    assert not svc.cancel(999) and not svc.cancel(active_victim.rid)
    svc.run_until_complete()
    assert active_victim.status == "cancelled" and active_victim.done
    assert queued_victim.status == "cancelled"
    assert queued_victim.generated == []
    assert keeper.status == "done"
    assert keeper.generated == _reference_tokens(params, cfg, prompts[0], 6)
    assert svc.stats["cancelled"] == 2
    assert svc.stats["preemptions"] == 1        # only the active victim
    _drained(svc)


def test_queue_full_rejects_with_diagnostics(dense):
    params, cfg = dense
    prompts = _prompts(cfg, [5, 5, 5])
    svc = GenerateService(params, cfg, max_batch=1, max_seq=MAX_SEQ,
                          page_size=4, max_queue=2)
    svc.submit(prompts[0], 2)
    svc.submit(prompts[1], 2)
    with pytest.raises(QueueFull) as ei:
        svc.submit(prompts[2], 2)
    assert ei.value.queue_depth == 2 and ei.value.max_queue == 2
    assert svc.stats["rejected"] == 1
    assert svc.stats["submitted"] == 2          # the reject never counted
    svc.run_until_complete()
    _drained(svc)


def test_service_stalled_carries_diagnostics(dense):
    params, cfg = dense
    (prompt,) = _prompts(cfg, [5])
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4)
    svc.submit(prompt, 10)
    with pytest.raises(ServiceStalled) as ei:
        svc.run_until_complete(max_steps=2)
    err = ei.value
    assert err.active_slots == 1 and err.queue_depth == 0
    assert err.steps == 2 and err.last_progress_tick == 1
    svc.run_until_complete()                    # budget was the only issue
    _drained(svc)


def test_degrade_ladder_walks_down_and_promotes_back(dense):
    """A faulted tick degrades one rung (bounded → gather on CPU) and
    sets an exponential-backoff cooldown of clean ticks; surviving the
    cooldown promotes back up."""
    params, cfg = dense
    (prompt,) = _prompts(cfg, [5])
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4, decode_path="bounded",
                          faults=FaultPlan([FaultEvent(1, "nan_decode",
                                                       sticky=1)]))
    assert svc._ladder == ("bounded", "gather")
    h = svc.submit(prompt, 8)
    paths = []
    while svc.step():
        paths.append(svc.decode_path_active)
    # paths[i] is the active rung *after* tick i.  Tick 0 is clean; tick
    # 1 faults -> degrade to gather with cooldown 2**1 = 2; ticks 2-3
    # burn the cooldown; tick 4 promotes back to bounded
    assert paths[0] == "bounded"
    assert paths[1:4] == ["gather"] * 3
    assert paths[4] == "bounded"
    assert h.status == "done"
    assert h.generated == _reference_tokens(params, cfg, prompt, 8)
    _drained(svc)


def test_guard_off_runs_and_refuses_injection(dense):
    params, cfg = dense
    (prompt,) = _prompts(cfg, [5])
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4, guard=False)
    with pytest.raises(ValueError, match="guard"):
        svc.inject(FaultPlan([FaultEvent(0, "admission_fail")]))
    h = svc.submit(prompt, 4)
    svc.run_until_complete()
    assert h.generated == _reference_tokens(params, cfg, prompt, 4)
    _drained(svc)


def test_chaos_trace_unaffected_requests_bitwise(dense):
    """The blast-radius contract on a mixed chaos trace: every request
    reaches a terminal state, pages are conserved, and any request the
    faults never touched (not preempted / cancelled / expired) has a
    token stream bitwise-identical to the fault-free replay.  Requests
    that recovered via retry or preemption must *also* match (greedy
    recovery is exact)."""
    params, cfg = dense
    trace = open_loop_trace(6, mean_interarrival=1.5, prompt_lens=(5, 7),
                            new_token_lens=(3, 5, 7), vocab_size=cfg.vocab,
                            seed=7)

    def run(faults):
        svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                              page_size=4)
        handles = replay(svc, trace, faults=faults)
        return svc, handles

    _, clean = run(None)
    plan = FaultPlan([FaultEvent(2, "nan_decode", sticky=1),
                      FaultEvent(4, "nan_decode", victim=1, sticky=3),
                      FaultEvent(5, "admission_fail"),
                      FaultEvent(6, "drop_prefill")])
    svc, chaotic = run(plan)
    assert svc.stats["retries"] >= 1 and svc.stats["preemptions"] >= 1
    for h_clean, h_chaos in zip(clean, chaotic):
        assert h_chaos.done and h_chaos.status == "done"
        assert h_chaos.generated == h_clean.generated, \
            f"rid={h_chaos.rid} diverged under chaos " \
            f"(faulted={h_chaos.rid in svc.faulted_rids})"
    _drained(svc)


def test_seeded_plan_terminates_everything(dense):
    """CI-chaos-smoke shape in miniature: a seeded Poisson fault plan
    over an open-loop trace — all requests terminal, pool conserved,
    failure counters consistent with what actually fired."""
    params, cfg = dense
    trace = open_loop_trace(5, mean_interarrival=1.0, prompt_lens=(5, 7),
                            new_token_lens=(3, 5), vocab_size=cfg.vocab,
                            seed=3)
    plan = FaultPlan.seeded(11, 24, p_nan=0.25, p_admission=0.15,
                            p_drop=0.1)
    assert plan.summary()["nan_decode"] >= 1
    svc = GenerateService(params, cfg, max_batch=2, max_seq=MAX_SEQ,
                          page_size=4)
    handles = replay(svc, trace, faults=plan)
    assert all(h.done for h in handles)
    assert svc.stats["retired"] == len(handles)
    fired = sum(1 for _, _, applied in svc.faults_fired if applied)
    assert svc.stats["faults_injected"] == fired
    _drained(svc)
