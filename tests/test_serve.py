"""Serving-tier conformance and plan-cache regression tests.

* **Token-for-token conformance**: the continuous-batching service —
  paged block pool, admission as a QuickSched conflict round, engine-run
  batched decode with requests joining and leaving mid-stream — must
  produce exactly the token stream the sequential
  ``serving.prefill``/``decode_step`` reference produces per request, for
  one arch of each supported family (dense, MoE+MLA, SSM).
* **Plan cache as compiled-module registry**: repeated batch shapes must
  hit ``core.plan``'s structural-hash cache; a new shape must miss
  exactly once (asserted via ``plan_cache_info()``).
* Admission safety + family gating edge cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.plan import clear_plan_cache, plan_cache_info
from repro.models import lm, serving
from repro.serve import AdmissionConflict, BlockPool, GenerateService

MAX_SEQ = 24
PLENS = (5, 7, 5, 9, 5)
BUDGETS = (4, 9, 2, 6, 1)       # ragged, incl. a prompt-only request


def _reference_tokens(params, cfg, prompt, n_new):
    """Sequential single-request greedy reference: one prefill, then
    B=1 ``decode_step`` against a dense (non-paged) cache."""
    logits, cache, pos = serving.prefill(params, cfg, prompt[None])
    if cfg.family != "ssm":
        cache = {k: jnp.pad(v, [(0, 0), (0, 0), (0, MAX_SEQ - v.shape[2])]
                            + [(0, 0)] * (v.ndim - 3))
                 for k, v in cache.items()}
    toks = [int(np.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, cache = serving.decode_step(
            params, cfg, cache, jnp.asarray([[toks[-1]]], jnp.int32), pos)
        toks.append(int(np.argmax(logits[0])))
        pos = pos + 1
    return toks


@pytest.mark.parametrize("arch,over", [
    ("qwen3-1.7b", {}),                             # dense
    ("deepseek-v3-671b", {"capacity_factor": 8.0}),  # moe + mla
    ("falcon-mamba-7b", {}),                        # ssm
])
def test_continuous_matches_sequential_reference(arch, over):
    cfg = get_config(arch).reduced(**over)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=pl, dtype=np.int32)
               for pl in PLENS]
    # max_batch < n_requests forces mid-stream joins as early requests
    # retire; ragged budgets force mid-stream leaves
    svc = GenerateService(params, cfg, max_batch=3, max_seq=MAX_SEQ,
                          page_size=4)
    handles = [svc.submit(p, n) for p, n in zip(prompts, BUDGETS)]
    svc.run_until_complete()
    for h, p, n in zip(handles, prompts, BUDGETS):
        assert h.done and len(h.generated) == n
        assert h.generated == _reference_tokens(params, cfg, p, n), \
            f"rid={h.rid} diverged from the sequential reference"
    assert svc.pool.allocated == 0      # every page returned
    svc.pool.check_invariants()
    eps = svc.compiled_entry_points()
    assert len(eps["decode_batch_sizes"]) > 1, \
        "expected multiple batch-size-specialized decode entry points"


def test_plan_cache_is_module_registry():
    """Identical batch shapes reuse the lowered plan (cache hit); a new
    shape (different admission batch / decode batch size) misses exactly
    once and is then itself reused."""
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    svc = GenerateService(params, cfg, max_batch=2, max_seq=16, page_size=4)
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab
    clear_plan_cache()

    svc.submit(prompt, 4)
    svc.submit(prompt, 4)
    svc.run_until_complete()
    info = plan_cache_info()
    # one admission shape (2 requests x 2 pages) + one decode shape (bs=2)
    assert info["misses"] == 2
    assert info["hits"] == 2            # 2 repeat decode ticks

    svc.submit(prompt, 4)
    svc.submit(prompt, 4)
    svc.run_until_complete()
    info2 = plan_cache_info()
    assert info2["misses"] == info["misses"], \
        "same batch shapes must not re-lower"
    assert info2["hits"] == info["hits"] + 4

    svc.submit(prompt, 3)               # new shapes: 1-request admission,
    svc.run_until_complete()            # bs=1 decode
    info3 = plan_cache_info()
    assert info3["misses"] == info2["misses"] + 2
    assert info3["hits"] == info2["hits"] + 1


def test_forged_double_assignment_refused():
    pool = BlockPool(6, page_size=4)
    batch = [pool.alloc(2, owner="a"), pool.alloc(2, owner="b")]
    batch[1] = list(batch[1]) + [batch[0][0]]       # bypasses alloc
    with pytest.raises(AdmissionConflict):
        pool.plan_admission(batch)


def test_unsupported_family_rejected():
    cfg = get_config("internvl2-76b").reduced()     # vlm needs extra inputs
    with pytest.raises(ValueError, match="families"):
        GenerateService({}, cfg)


def test_oversized_request_rejected():
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    svc = GenerateService(params, cfg, max_batch=1, max_seq=8, page_size=4)
    with pytest.raises(ValueError, match="positions"):
        svc.submit(np.zeros(4, np.int32), 32)
