"""Tiled-QR application tests (paper §4.1): task-graph structure, numerical
correctness through the QuickSched executors, schedule properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.apps import qr
from repro.core import conflict_rounds, simulate, validate_rounds


def rand_matrix(n, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal((n, n)),
                       dtype=jnp.float32)


class TestGraphStructure:
    def test_paper_task_and_resource_counts(self):
        """2048² matrix, 64² tiles → 32×32 grid: the paper reports 11 440
        tasks, 1 024 resources, 21 856 locks, 11 408 uses."""
        c = qr.paper_counts(32, 32)
        assert c["tasks"] == 11440
        assert c["resources"] == 1024
        assert c["locks"] == 21856
        assert c["uses"] == 11408
        # The paper reports 21 824 dependencies; the fully-deterministic
        # table structure (which we implement) carries the per-tile
        # previous-level chains explicitly:
        assert c["deps"] == 32240

    def test_task_type_counts(self):
        s, _ = qr.make_qr_graph(32, 32)
        by_type = {}
        for t in s.tasks:
            by_type[t.type] = by_type.get(t.type, 0) + 1
        assert by_type[qr.T_GEQRF] == 32
        assert by_type[qr.T_LARFT] == 496
        assert by_type[qr.T_TSQRF] == 496
        assert by_type[qr.T_SSRFT] == 10416

    def test_geqrf_on_critical_path(self):
        """Paper: 'the DGEQRF tasks all lie on the longest critical path'.
        Each DGEQRF must have the maximum weight among ready tasks at its
        level."""
        s, _ = qr.make_qr_graph(8, 8)
        s.prepare()
        w = {t.tid: t.weight for t in s.tasks}
        geqrf = [t for t in s.tasks if t.type == qr.T_GEQRF]
        # DGEQRF(k) weight decreases with k and dominates its level
        ws = [t.weight for t in sorted(geqrf, key=lambda t: t.data[2])]
        assert all(a > b for a, b in zip(ws, ws[1:]))
        top = max(w.values())
        assert ws[0] == top, "DGEQRF(0) must head the critical path"

    def test_rounds_valid(self):
        s, _ = qr.make_qr_graph(6, 6)
        rounds = conflict_rounds(s, nr_lanes=8)
        validate_rounds(s, rounds)


class TestNumerics:
    @pytest.mark.parametrize("mode", ["sequential", "rounds"])
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_qr_correct(self, mode, backend):
        n, b = 96, 32
        a = rand_matrix(n)
        r, _ = qr.run_qr(a, tile=b, mode=mode, backend=backend, nr_queues=4)
        r = np.asarray(r)
        # R is upper triangular
        assert np.abs(np.tril(r, -1)).max() < 1e-4
        # Cholesky identity R^T R == A^T A
        lhs, rhs = r.T @ r, np.asarray(a).T @ np.asarray(a)
        assert np.abs(lhs - rhs).max() / np.abs(rhs).max() < 1e-4

    def test_qr_matches_lapack_up_to_signs(self):
        n, b = 64, 16
        a = rand_matrix(n, seed=3)
        r, _ = qr.run_qr(a, tile=b, mode="sequential", backend="ref")
        r = np.asarray(r)
        r_ref = np.asarray(jnp.linalg.qr(a, mode="r"))
        sign = np.sign(np.diag(r)) * np.sign(np.diag(r_ref))
        assert_allclose(r * sign[:, None], r_ref, atol=2e-3)

    def test_threaded_qr_correct(self):
        """The pthread-pool analogue with real locks must produce a valid R
        (exercises conflict exclusion on the diagonal/row tiles)."""
        n, b = 64, 16
        a = rand_matrix(n, seed=9)
        r, _ = qr.run_qr(a, tile=b, mode="threaded", backend="ref",
                         nr_queues=4)
        r = np.asarray(r)
        rhs = np.asarray(a).T @ np.asarray(a)
        assert np.abs(r.T @ r - rhs).max() / np.abs(rhs).max() < 1e-4

    def test_jit_traced_schedule(self):
        """The sequential executor traces into a single jitted program."""
        n, b = 64, 16
        a = rand_matrix(n, seed=11)

        @jax.jit
        def qr_program(x):
            r, _ = qr.run_qr(x, tile=b, mode="sequential", backend="ref")
            return r

        r = np.asarray(qr_program(a))
        rhs = np.asarray(a).T @ np.asarray(a)
        assert np.abs(r.T @ r - rhs).max() / np.abs(rhs).max() < 1e-4


class TestScaling:
    def test_simulated_strong_scaling(self):
        """Scheduler-limited efficiency on the paper's 32×32 grid should be
        high at 64 workers (paper: 73% incl. hardware effects)."""
        def make(n):
            s, _ = qr.make_qr_graph(32, 32, nr_queues=n)
            return s
        r1 = simulate(make(1), 1)
        r64 = simulate(make(64), 64)
        eff = r1.makespan / (64 * r64.makespan)
        assert eff > 0.70, f"64-worker efficiency {eff:.2f} below paper's 73%"

    def test_schedule_validates(self):
        s, _ = qr.make_qr_graph(12, 12, nr_queues=8)
        res = simulate(s, 8)
        s.validate_schedule(res.timeline)
