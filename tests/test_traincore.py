"""Substrate tests: optimizers, checkpoint atomicity + resharding restore,
bit-identical failure recovery, deterministic data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, cosine_schedule,
                         global_norm)
from repro.trainer.loop import InjectedFailure, run_training


class TestOptimizers:
    def _quadratic(self, params):
        return sum(jnp.sum(p * p) for p in jax.tree.leaves(params))

    @pytest.mark.parametrize("kind", ["adamw", "adafactor"])
    def test_optimizer_descends(self, kind):
        params = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
        if kind == "adamw":
            state = adamw_init(params)
            upd = lambda g, s, p: adamw_update(g, s, p, lr=0.05, wd=0.0)
        else:
            state = adafactor_init(params)
            upd = lambda g, s, p: adafactor_update(g, s, p, lr=0.05)
        loss0 = float(self._quadratic(params))
        for _ in range(50):
            grads = jax.grad(self._quadratic)(params)
            params, state = upd(grads, state, params)
        assert float(self._quadratic(params)) < 0.2 * loss0

    def test_adafactor_memory_is_factored(self):
        params = {"w": jnp.ones((256, 512))}
        state = adafactor_init(params)
        n_state = sum(x.size for x in jax.tree.leaves(state.inner))
        assert n_state == 256 + 512, "second moment must be row+col factored"

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
        assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=110)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-6)
        assert float(lr(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "n": {"b": jnp.ones((2,), jnp.int32)}}
        save_checkpoint(str(tmp_path), 5, tree)
        assert latest_step(str(tmp_path)) == 5
        out = restore_checkpoint(str(tmp_path), 5, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert_allclose(np.asarray(a), np.asarray(b))

    def test_atomicity_no_partial_visible(self, tmp_path):
        """A .tmp directory must never be picked up as a checkpoint."""
        tree = {"a": jnp.ones((4,))}
        save_checkpoint(str(tmp_path), 1, tree)
        os.makedirs(tmp_path / "step_00000002.tmp")
        assert latest_step(str(tmp_path)) == 1

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.ones((2,))}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        names = sorted(os.listdir(tmp_path))
        assert names == ["step_00000003", "step_00000004"]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
        tree = {"a": jnp.arange(1000.0)}
        mgr.save(7, tree)
        mgr.wait()
        out = mgr.restore(7, tree)
        assert_allclose(np.asarray(out["a"]), np.arange(1000.0))

    def test_resharding_restore(self, tmp_path):
        """Save under one sharding, restore under another (elastic)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        save_checkpoint(str(tmp_path), 1, tree)
        shd = {"w": NamedSharding(mesh, P("data", None))}
        out = restore_checkpoint(str(tmp_path), 1, tree, shardings=shd)
        assert out["w"].sharding.spec == P("data", None)
        assert_allclose(np.asarray(out["w"]),
                        np.arange(64.0).reshape(8, 8))


class TestDataPipeline:
    def test_deterministic_restart(self):
        d1 = SyntheticTokens(1000, 32, 4, seed=3)
        d2 = SyntheticTokens(1000, 32, 4, seed=3)
        assert (d1.batch_at(17)["tokens"] == d2.batch_at(17)["tokens"]).all()

    def test_shards_disjoint_streams(self):
        a = SyntheticTokens(1000, 32, 8, seed=3, shard_id=0, num_shards=2)
        b = SyntheticTokens(1000, 32, 8, seed=3, shard_id=1, num_shards=2)
        assert not (a.batch_at(0)["tokens"] == b.batch_at(0)["tokens"]).all()

    def test_learnable_structure(self):
        d = SyntheticTokens(100, 64, 4, seed=0, noise=0.0)
        t = d.batch_at(0)["tokens"]
        # noiseless stream follows the permutation exactly
        assert (t[:, 1:] == d.perm[t[:, :-1]]).all()


class TestFaultTolerance:
    def test_failure_recovery_bit_identical(self, tmp_path):
        """Train A: uninterrupted 20 steps.  Train B: killed at step 12,
        restarted, resumed from ckpt.  Final losses must match exactly."""
        cfg = get_config("qwen3-1.7b").reduced(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=256)
        common = dict(steps=20, seq_len=32, global_batch=4,
                      ckpt_every=5, log_every=100, log_fn=lambda s: None)
        _, _, hist_a = run_training(cfg, str(tmp_path / "a"), **common)
        with pytest.raises(InjectedFailure):
            run_training(cfg, str(tmp_path / "b"), fail_at_step=12, **common)
        _, _, hist_b = run_training(cfg, str(tmp_path / "b"), **common)
        tail_a = dict(hist_a)
        for step, loss in hist_b:
            assert tail_a[step] == pytest.approx(loss, rel=1e-6), (
                f"divergence at step {step} after restart")
