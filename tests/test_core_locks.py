"""Unit + property tests for the hierarchical lock/hold protocol (paper §3.2)."""

import random
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.locks import SeqLockManager, ThreadedLockManager


def chain_parents(depth):
    # resource i's parent is i-1; root is 0
    return [-1] + list(range(depth - 1))


class TestBasicProtocol:
    def test_lock_unlock_roundtrip(self):
        lm = SeqLockManager([-1])
        assert lm.try_lock(0)
        assert lm.is_locked(0)
        assert not lm.try_lock(0), "double lock must fail"
        lm.unlock(0)
        assert lm.all_free()

    def test_locked_child_holds_ancestors(self):
        lm = SeqLockManager(chain_parents(4))
        assert lm.try_lock(3)
        for a in (0, 1, 2):
            assert lm.hold_count(a) == 1
            assert not lm.try_lock(a), "held ancestor must not lock"
        lm.unlock(3)
        assert lm.all_free()

    def test_locked_ancestor_blocks_descendant(self):
        lm = SeqLockManager(chain_parents(4))
        assert lm.try_lock(1)
        assert not lm.try_lock(3), "descendant of locked resource must fail"
        assert not lm.try_lock(2)
        lm.unlock(1)
        assert lm.try_lock(3)
        lm.unlock(3)
        assert lm.all_free()

    def test_siblings_coexist(self):
        # root 0 with children 1 and 2
        lm = SeqLockManager([-1, 0, 0])
        assert lm.try_lock(1)
        assert lm.try_lock(2)
        assert lm.hold_count(0) == 2
        lm.unlock(1)
        assert lm.hold_count(0) == 1
        assert not lm.try_lock(0)
        lm.unlock(2)
        assert lm.try_lock(0)
        lm.unlock(0)
        assert lm.all_free()

    def test_lock_all_is_atomic(self):
        lm = SeqLockManager([-1, -1, -1])
        assert lm.try_lock(1)
        assert not lm.lock_all([0, 1, 2])
        # failure must leave 0 unlocked (rollback)
        assert not lm.is_locked(0) and not lm.is_locked(2)
        lm.unlock(1)
        assert lm.lock_all([0, 1, 2])
        lm.unlock_all([0, 1, 2])
        assert lm.all_free()


@st.composite
def resource_forest(draw):
    n = draw(st.integers(min_value=1, max_value=30))
    parents = [-1]
    for i in range(1, n):
        parents.append(draw(st.integers(min_value=-1, max_value=i - 1)))
    return parents


@settings(max_examples=200, deadline=None)
@given(forest=resource_forest(), data=st.data())
def test_property_lock_invariants(forest, data):
    """After any sequence of lock/unlock ops: (1) a locked resource has no
    locked strict ancestor/descendant; (2) hold counts equal the number of
    locked resources strictly below; (3) full release restores all-free."""
    lm = SeqLockManager(forest)
    n = len(forest)
    locked = set()
    ops = data.draw(st.lists(st.integers(min_value=0, max_value=n - 1),
                             max_size=60))
    for r in ops:
        if r in locked and data.draw(st.booleans()):
            lm.unlock(r)
            locked.discard(r)
        else:
            if lm.try_lock(r):
                locked.add(r)

    def ancestors(r):
        out = []
        u = forest[r]
        while u != -1:
            out.append(u)
            u = forest[u]
        return out

    for r in locked:
        for a in ancestors(r):
            assert a not in locked, "ancestor and descendant both locked"
    for a in range(n):
        expect = sum(1 for r in locked for x in ancestors(r) if x == a)
        assert lm.hold_count(a) == expect, f"hold count wrong at {a}"
    for r in list(locked):
        lm.unlock(r)
    assert lm.all_free()


def test_threaded_lock_exclusion_stress():
    """N threads hammer overlapping lock sets; assert mutual exclusion and
    conserved counters (the paper's CAS protocol, mutex-emulated)."""
    parents = [-1, 0, 0, 1, 1, 2, 2]  # small tree
    lm = ThreadedLockManager(parents)
    in_crit = {r: 0 for r in range(len(parents))}
    crit_mutex = threading.Lock()
    errors = []
    N_ITER = 300

    def worker(seed):
        rng = random.Random(seed)
        try:
            for _ in range(N_ITER):
                r = rng.randrange(len(parents))
                if lm.try_lock(r):
                    with crit_mutex:
                        in_crit[r] += 1
                        assert in_crit[r] == 1, "mutual exclusion violated"
                    with crit_mutex:
                        in_crit[r] -= 1
                    lm.unlock(r)
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert lm.all_free()
