"""Conflict-freedom as a property (hypothesis): over randomized task
forests — random dependency DAGs locking random resources in random
resource forests — no ``ExecutionPlan`` round and no engine descriptor
slab ever co-schedules two tasks whose locked resource subtrees overlap.

This is the invariant everything downstream leans on: the rounds mode may
dispatch a round's batches in any order, and the engine megakernel walks a
slab sequentially but could legally walk it in parallel, precisely because
no two tasks of a slab can touch the same resource subtree (DESIGN.md
§Engine)."""

from hypothesis import given, settings
from hypothesis import strategies as st

from repro import engine
from repro.core import FLAG_VIRTUAL, BatchSpec, QSched, lower

N_TYPES = 3
PAD = N_TYPES


@st.composite
def task_forests(draw):
    """A QSched over a random resource *forest* (each resource's parent is
    an earlier resource or none) with random dependencies (i → j, i < j)
    and random per-task lock sets that avoid self-unsatisfiable
    ancestor/descendant combinations (those can never be acquired by one
    task and are rejected at runtime, not a scheduling property)."""
    n = draw(st.integers(1, 24))
    nres = draw(st.integers(1, 8))
    s = QSched(nr_queues=draw(st.integers(1, 4)))
    parents = []
    for r in range(nres):
        parent = draw(st.integers(-1, r - 1)) if r else -1
        parents.append(parent)
        s.addres(owner=draw(st.integers(-1, 3)), parent=parent)

    def chain(r):
        out = {r}
        while parents[r] != -1:
            r = parents[r]
            out.add(r)
        return out

    for i in range(n):
        flags = FLAG_VIRTUAL if draw(st.booleans()) and i % 5 == 0 else 0
        s.addtask(type=draw(st.integers(0, N_TYPES - 1)),
                  data=i, cost=draw(st.floats(0.1, 10.0)), flags=flags)
    for j in range(1, n):
        for i in draw(st.lists(st.integers(0, j - 1), max_size=3,
                               unique=True)):
            s.addunlock(i, j)
    for i in range(n):
        taken = set()
        for r in draw(st.lists(st.integers(0, nres - 1), max_size=3,
                               unique=True)):
            if any(r in chain(q) or q in chain(r) for q in taken):
                continue
            taken.add(r)
            s.addlock(i, r)
    return s, parents


def _assert_subtrees_disjoint(sched, parents, tids, what):
    """No resource locked twice, and no locked resource lies on another
    locked resource's ancestor chain — the paper's §3.2 hierarchical
    exclusion, stated over a whole round."""
    locks_of = sched.graph.locks_list
    locked = set()
    for tid in tids:
        for r in locks_of[tid]:
            assert r not in locked, f"{what}: resource {r} locked twice"
            locked.add(r)
    for r in locked:
        u = parents[r]
        while u != -1:
            assert u not in locked, \
                f"{what}: resource {r} and ancestor {u} both locked"
            u = parents[u]


@given(task_forests(), st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_plan_rounds_and_engine_slabs_conflict_free(forest, nr_lanes):
    sched, parents = forest
    plan = lower(sched, nr_lanes, cache=False)
    registry = {tt: BatchSpec(
        run_one=lambda tid, data: None,
        encode=lambda tid, data, tt=tt: [(tt, tid)])
        for tt in range(N_TYPES)}
    tables = engine.lower_tables(plan, sched, registry,
                                 arg_width=1, pad_type=PAD)
    assert tables.nr_rounds == plan.nr_rounds

    flags = sched._tflags
    seen = []
    for r, rnd in enumerate(plan.rounds):
        _assert_subtrees_disjoint(sched, parents, rnd.tids, f"round {r}")
        slab_tids = tables.round_tids(r)
        _assert_subtrees_disjoint(sched, parents, set(slab_tids),
                                  f"slab {r}")
        # a slab holds exactly its round's non-virtual tasks
        expect = sorted(t for t in rnd.tids if not flags[t] & FLAG_VIRTUAL)
        assert sorted(set(slab_tids)) == expect
        seen += slab_tids
    # every non-virtual task encoded exactly once (1 row/task registry)
    assert sorted(seen) == [t for t in range(sched.nr_tasks)
                            if not flags[t] & FLAG_VIRTUAL]


@given(task_forests())
@settings(max_examples=30, deadline=None)
def test_slab_pads_are_noops(forest):
    sched, _ = forest
    plan = lower(sched, 2, cache=False)
    registry = {tt: BatchSpec(
        run_one=lambda tid, data: None,
        encode=lambda tid, data, tt=tt: [(tt, tid)])
        for tt in range(N_TYPES)}
    tables = engine.lower_tables(plan, sched, registry,
                                 arg_width=1, pad_type=PAD)
    for r in range(tables.nr_rounds):
        w = int(tables.lengths[r])
        assert (tables.desc[r, w:, 0] == PAD).all()
        assert (tables.tids[r, w:] == -1).all()
        assert (tables.desc[r, :w, 0] < PAD).all()
