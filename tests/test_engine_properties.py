"""Conflict-freedom and phase-coloring as properties (hypothesis): over
randomized task forests — random dependency DAGs locking random resources
in random resource forests — no ``ExecutionPlan`` round and no engine
round slice ever co-schedules two tasks whose locked resource subtrees
overlap, and the write-coloring pass never co-phases two work items that
touch a common state row.

These are the invariants everything downstream leans on: the rounds mode
may dispatch a round's batches in any order, and the engine megakernel may
walk a sub-phase's item blocks in any order — or in parallel grid
programs — precisely because no two tasks of a round touch the same
resource subtree and no two items of a phase touch the same state row
(DESIGN.md §Engine, "Ragged tables & grid walk")."""

from hypothesis import given, settings
from hypothesis import strategies as st

from repro import engine
from repro.core import FLAG_VIRTUAL, BatchSpec, QSched, color_phases, lower

N_TYPES = 3


@st.composite
def task_forests(draw):
    """A QSched over a random resource *forest* (each resource's parent is
    an earlier resource or none) with random dependencies (i → j, i < j)
    and random per-task lock sets that avoid self-unsatisfiable
    ancestor/descendant combinations (those can never be acquired by one
    task and are rejected at runtime, not a scheduling property)."""
    n = draw(st.integers(1, 24))
    nres = draw(st.integers(1, 8))
    s = QSched(nr_queues=draw(st.integers(1, 4)))
    parents = []
    for r in range(nres):
        parent = draw(st.integers(-1, r - 1)) if r else -1
        parents.append(parent)
        s.addres(owner=draw(st.integers(-1, 3)), parent=parent)

    def chain(r):
        out = {r}
        while parents[r] != -1:
            r = parents[r]
            out.add(r)
        return out

    for i in range(n):
        flags = FLAG_VIRTUAL if draw(st.booleans()) and i % 5 == 0 else 0
        s.addtask(type=draw(st.integers(0, N_TYPES - 1)),
                  data=i, cost=draw(st.floats(0.1, 10.0)), flags=flags)
    for j in range(1, n):
        for i in draw(st.lists(st.integers(0, j - 1), max_size=3,
                               unique=True)):
            s.addunlock(i, j)
    for i in range(n):
        taken = set()
        for r in draw(st.lists(st.integers(0, nres - 1), max_size=3,
                               unique=True)):
            if any(r in chain(q) or q in chain(r) for q in taken):
                continue
            taken.add(r)
            s.addlock(i, r)
    return s, parents


def _assert_subtrees_disjoint(sched, parents, tids, what):
    """No resource locked twice, and no locked resource lies on another
    locked resource's ancestor chain — the paper's §3.2 hierarchical
    exclusion, stated over a whole round."""
    locks_of = sched.graph.locks_list
    locked = set()
    for tid in tids:
        for r in locks_of[tid]:
            assert r not in locked, f"{what}: resource {r} locked twice"
            locked.add(r)
    for r in locked:
        u = parents[r]
        while u != -1:
            assert u not in locked, \
                f"{what}: resource {r} and ancestor {u} both locked"
            u = parents[u]


@given(task_forests(), st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_plan_rounds_and_engine_slices_conflict_free(forest, nr_lanes):
    sched, parents = forest
    plan = lower(sched, nr_lanes, cache=False)
    registry = {tt: BatchSpec(
        run_one=lambda tid, data: None,
        encode=lambda tid, data, tt=tt: [(tt, tid)])
        for tt in range(N_TYPES)}
    tables = engine.lower_tables(plan, sched, registry, arg_width=1)
    assert tables.nr_rounds == plan.nr_rounds

    flags = sched._tflags
    seen = []
    for r, rnd in enumerate(plan.rounds):
        _assert_subtrees_disjoint(sched, parents, rnd.tids, f"round {r}")
        slice_tids = tables.round_tids(r)
        _assert_subtrees_disjoint(sched, parents, set(slice_tids),
                                  f"slice {r}")
        # a round's CSR slice holds exactly its non-virtual tasks
        expect = sorted(t for t in rnd.tids if not flags[t] & FLAG_VIRTUAL)
        assert sorted(set(slice_tids)) == expect
        seen += slice_tids
    # every non-virtual task encoded exactly once (1 row/task registry)
    assert sorted(seen) == [t for t in range(sched.nr_tasks)
                            if not flags[t] & FLAG_VIRTUAL]


@given(task_forests())
@settings(max_examples=30, deadline=None)
def test_tables_are_ragged_with_no_pad_rows(forest):
    """CSR invariants: rounds partition the flat row array exactly, every
    row carries a real engine type (the no-op types exist only as the
    kernels' defensive clamp branch), and phases partition each round."""
    sched, _ = forest
    plan = lower(sched, 2, cache=False)
    registry = {tt: BatchSpec(
        run_one=lambda tid, data: None,
        encode=lambda tid, data, tt=tt: [(tt, tid)])
        for tt in range(N_TYPES)}
    tables = engine.lower_tables(plan, sched, registry, arg_width=1)
    assert tables.stats["pad_rows"] == 0
    assert tables.stats["pad_fraction"] == 0.0
    assert int(tables.round_offsets[-1]) == tables.nr_items
    assert (tables.desc[:, 0] < N_TYPES).all()
    assert int(tables.round_lengths.sum()) == tables.nr_items
    for r in range(tables.nr_rounds):
        bounds = tables.round_phases(r).tolist()
        assert bounds[0] == int(tables.round_offsets[r])
        assert bounds[-1] == int(tables.round_offsets[r + 1])
        assert all(b1 > b0 for b0, b1 in zip(bounds, bounds[1:]))


@st.composite
def access_sequences(draw):
    """Random (reads, writes) item sequences over a small key space, with
    deliberate destination collisions (the accumulation-row shape)."""
    n = draw(st.integers(0, 30))
    items = []
    for _ in range(n):
        writes = draw(st.lists(st.integers(0, 5), min_size=1, max_size=2,
                               unique=True))
        reads = draw(st.lists(st.integers(0, 5), max_size=3, unique=True))
        items.append((tuple(reads), tuple(writes)))
    return items


@given(access_sequences())
@settings(max_examples=80, deadline=None)
def test_color_phases_invariants(items):
    """The write-coloring pass: phases are contiguous and cover the items
    exactly; within a phase no two items share a write key and no item
    reads a key another writes; items that conflict keep their original
    relative order (strictly increasing phase), so per-destination
    accumulation order is preserved."""
    bounds = color_phases(items)
    assert bounds[0] == 0 and bounds[-1] == len(items)
    assert all(b1 > b0 for b0, b1 in zip(bounds, bounds[1:]))

    phase_of = {}
    for p, (b0, b1) in enumerate(zip(bounds, bounds[1:])):
        reads, writes = set(), set()
        for i in range(b0, b1):
            r, w = set(items[i][0]), set(items[i][1])
            assert not (w & writes), "write/write overlap within a phase"
            assert not (w & reads) and not (r & writes), \
                "read/write overlap within a phase"
            reads |= r
            writes |= w
            phase_of[i] = p
    for i in range(len(items)):
        ri, wi = set(items[i][0]), set(items[i][1])
        for j in range(i + 1, len(items)):
            rj, wj = set(items[j][0]), set(items[j][1])
            if (wi & wj) or (wi & rj) or (ri & wj):
                assert phase_of[i] < phase_of[j], \
                    "conflicting items must keep their order across phases"


@given(task_forests(), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_lowered_phases_respect_row_access(forest, nr_lanes):
    """End to end through ``lower_tables``: with a row-access map that
    collides tasks onto a tiny destination space, no two items of any
    lowered sub-phase share a destination row."""
    sched, _ = forest
    plan = lower(sched, nr_lanes, cache=False)
    registry = {tt: BatchSpec(
        run_one=lambda tid, data: None,
        encode=lambda tid, data, tt=tt: [(tt, tid, tid % 3)])
        for tt in range(N_TYPES)}

    def row_access(row):
        return (), (row[2],)     # destination = tid % 3

    tables = engine.lower_tables(plan, sched, registry, arg_width=2,
                                 row_access=row_access)
    for r in range(tables.nr_rounds):
        bounds = tables.round_phases(r).tolist()
        for b0, b1 in zip(bounds, bounds[1:]):
            dests = [int(tables.desc[q, 2]) for q in range(b0, b1)]
            assert len(dests) == len(set(dests)), \
                "destination row repeated within one sub-phase"
