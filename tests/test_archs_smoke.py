"""Per-architecture smoke tests (deliverable f): every assigned arch at
reduced scale runs one forward + one train step on CPU — output shapes
check out and nothing goes NaN — plus a prefill→decode consistency check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config
from repro.models import lm, serving
from repro.trainer.steps import make_train_step


def make_batch(cfg, b=2, s=32, seed=0):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vis_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (b, cfg.n_vis_tokens, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (b, cfg.enc_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    hidden, aux = lm.forward(params, cfg, batch["tokens"], extra=batch)
    expect_s = 32 + (cfg.n_vis_tokens if cfg.family == "vlm" else 0)
    assert hidden.shape == (2, expect_s, cfg.d_model)
    logits = lm.logits_fn(params, cfg, hidden)
    assert logits.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    step, opt_init = make_train_step(cfg, optimizer="adamw", lr=1e-3)
    opt_state = opt_init(params)
    batch = make_batch(cfg)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Prefill S-1 tokens then decode token S-1 == full forward at S-1.
    MoE archs use a no-drop capacity factor so routing is identical."""
    cfg = get_config(arch).reduced(capacity_factor=8.0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, b=B, s=S, seed=3)
    tokens = batch["tokens"]
    hidden, _ = lm.forward(params, cfg, tokens, extra=batch)
    logits_full = lm.logits_fn(params, cfg, hidden[:, -1])
    logits_pf, cache, pos = serving.prefill(params, cfg, tokens[:, :S - 1],
                                            extra=batch)
    vis = cfg.n_vis_tokens if cfg.family == "vlm" else 0

    def pad(a):
        if a.ndim >= 4 and a.shape[2] == S - 1 + vis:
            padding = [(0, 0)] * a.ndim
            padding[2] = (0, 4)
            return jnp.pad(a, padding)
        if a.ndim == 4 and a.shape[2] == S - 1 + vis:
            padding = [(0, 0)] * a.ndim
            padding[2] = (0, 4)
            return jnp.pad(a, padding)
        return a

    cache = jax.tree.map(pad, cache)
    logits_dec, _ = serving.decode_step(params, cfg, cache,
                                        tokens[:, S - 1:S], pos)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-7b"])
def test_long_context_state_is_constant_size(arch):
    """long_500k rationale: decode state size must be independent of the
    sequence length for the sub-quadratic archs (trunk state only)."""
    cfg = get_config(arch).reduced()
    c1 = serving.init_cache(cfg, batch=1, max_seq=64)
    c2 = serving.init_cache(cfg, batch=1, max_seq=4096)
    trunk_keys = [k for k in c1 if k != "shared"]
    for k in trunk_keys:
        s1 = jax.tree.map(lambda a: a.shape, c1[k])
        s2 = jax.tree.map(lambda a: a.shape, c2[k])
        assert s1 == s2, f"{k} grows with context"


def test_param_count_model_matches_actual():
    """Analytic param model (used for roofline MODEL_FLOPS) within 2% of
    the real tree for the reduced configs."""
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        model = cfg.param_count()
        rel = abs(model - actual) / actual
        assert rel < 0.10, f"{arch}: model {model} vs actual {actual} ({rel:.1%})"


def test_full_config_param_counts():
    """Sanity-check the headline parameter counts of the full configs."""
    expect = {
        "deepseek-v3-671b": (6.0e11, 7.4e11),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "internvl2-76b": (6.0e10, 8.5e10),
        "falcon-mamba-7b": (6.0e9, 8.5e9),
        "granite-8b": (7.0e9, 9.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"
