"""Scheduler behaviour tests: weights, queue, dependencies, conflicts,
simulation, static rounds, threaded execution (paper §3–§4)."""

import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    QSched,
    SequentialExecutor,
    TaskQueue,
    conflict_rounds,
    critical_path_length,
    critical_path_weights,
    simulate,
    toposort,
    validate_rounds,
)


def fig1_graph(nq=1, **kw):
    """The paper's Figure 1 DAG: A->B->C, A->D->E(+B->E? no) ...
    We encode: A unlocks B,D; B unlocks C; D,F unlock E; G unlocks F,H,I;
    J unlocks K.  (Shape chosen to include a multi-dependency task E.)"""
    s = QSched(nr_queues=nq, **kw)
    ids = {name: s.addtask(type=0, data=name) for name in "ABCDEFGHIJK"}
    for a, b in [("A", "B"), ("A", "D"), ("B", "C"), ("D", "E"), ("F", "E"),
                 ("G", "F"), ("G", "H"), ("G", "I"), ("J", "K")]:
        s.addunlock(ids[a], ids[b])
    return s, ids


class TestWeights:
    def test_toposort_linear(self):
        assert toposort(3, [[1], [2], []]) == [0, 1, 2]

    def test_cycle_detection(self):
        with pytest.raises(ValueError, match="cycle"):
            toposort(2, [[1], [0]])

    def test_paper_weight_recurrence(self):
        # chain 0->1->2 with costs 1,2,3: weights 6,5,3
        w, _ = critical_path_weights(3, [[1], [2], []], [1, 2, 3])
        assert w == [6, 5, 3]

    def test_weight_takes_max_branch(self):
        # 0 unlocks 1 (cost 10) and 2 (cost 1)
        w, _ = critical_path_weights(3, [[1, 2], [], []], [1, 10, 1])
        assert w[0] == 11

    def test_critical_path_length(self):
        assert critical_path_length(3, [[1], [2], []], [1, 2, 3]) == 6


class TestQueue:
    def test_max_heap_priority_order(self):
        weights = [5.0, 9.0, 1.0, 7.0]
        q = TaskQueue(weights)
        for t in range(4):
            q.put(t)
        got = [q.get(lambda _: True) for _ in range(4)]
        assert got == [1, 3, 0, 2], "must pop in descending weight order"

    def test_skips_unlockable(self):
        weights = [5.0, 9.0]
        q = TaskQueue(weights)
        q.put(0)
        q.put(1)
        # task 1 (heavier) is conflicted; expect task 0
        assert q.get(lambda t: t != 1) == 0
        assert len(q) == 1

    def test_heap_invariant_after_middle_removal(self):
        import random
        rng = random.Random(0)
        weights = [rng.random() for _ in range(100)]
        q = TaskQueue(weights)
        for t in range(100):
            q.put(t)
        blocked = set(rng.sample(range(100), 50))
        for _ in range(30):
            q.get(lambda t: t not in blocked)
            assert q.check_heap(), "heap invariant broken"


class TestSchedulerProtocol:
    def test_fig1_executes_all_in_valid_order(self):
        s, ids = fig1_graph()
        s.prepare()
        seen = []
        SequentialExecutor(s).run(lambda ty, d: seen.append(d))
        assert sorted(seen) == sorted("ABCDEFGHIJK")
        pos = {n: i for i, n in enumerate(seen)}
        for a, b in [("A", "B"), ("B", "C"), ("D", "E"), ("F", "E"),
                     ("G", "F"), ("J", "K")]:
            assert pos[a] < pos[b]

    def test_conflicts_serialize_but_any_order(self):
        # Paper Fig 2: tasks F,H,I conflict via one resource.
        s = QSched(nr_queues=2)
        r = s.addres()
        tids = [s.addtask(data=i, cost=1.0) for i in range(3)]
        for t in tids:
            s.addlock(t, r)
        res = simulate(s, 2)
        s.validate_schedule(res.timeline)
        # serialized: makespan == 3 even with 2 workers
        assert res.makespan == pytest.approx(3.0)

    def test_hierarchical_conflicts(self):
        # parent resource locked by task P; leaf tasks lock children
        s = QSched(nr_queues=4)
        root = s.addres()
        kids = [s.addres(parent=root) for _ in range(4)]
        tp = s.addtask(data="P", cost=1.0)
        s.addlock(tp, root)
        for k in kids:
            t = s.addtask(data="L", cost=1.0)
            s.addlock(t, k)
        res = simulate(s, 4)
        s.validate_schedule(res.timeline)
        # P excludes all leaves: makespan >= 2 (1 for P + 1 round of leaves)
        assert res.makespan == pytest.approx(2.0)

    def test_virtual_tasks_not_executed(self):
        from repro.core import FLAG_VIRTUAL
        s = QSched()
        a = s.addtask(data="A")
        v = s.addtask(data="V", flags=FLAG_VIRTUAL)
        b = s.addtask(data="B")
        s.addunlock(a, v)
        s.addunlock(v, b)
        seen = []
        SequentialExecutor(s).run(lambda ty, d: seen.append(d))
        assert seen == ["A", "B"]

    def test_rerun_same_sched(self):
        s, _ = fig1_graph()
        out1 = simulate(s, 2).makespan
        out2 = simulate(s, 2).makespan  # qsched can be run more than once
        assert out1 == out2

    def test_critical_path_priority_beats_fifo(self):
        """The paper's QR claim: critical-path weights schedule long chains
        first.  Graph: one chain of length 8 + 14 independent unit tasks on
        2 workers.  Weighted: makespan 8 (chain on one worker, fillers on
        the other).  A weight-blind schedule can reach 8+ but typically 11+
        when fillers run first; we check the weighted one is optimal."""
        def build():
            s = QSched(nr_queues=2)
            prev = None
            for i in range(8):
                t = s.addtask(data=f"c{i}", cost=1.0)
                if prev is not None:
                    s.addunlock(prev, t)
                prev = t
            for i in range(14):
                s.addtask(data=f"f{i}", cost=1.0)
            return s
        res = simulate(build(), 2)
        assert res.makespan == pytest.approx(11.0, abs=3.1)
        # lower bound: (8 + 14) / 2 = 11; critical path = 8
        assert res.makespan >= 11.0 - 1e-9
        assert res.makespan == pytest.approx(11.0), (
            "critical-path priority should reach the optimal makespan")


class TestWorkStealingAndAffinity:
    def test_enqueue_prefers_owner_queue(self):
        s = QSched(nr_queues=3, reown=False)
        r = s.addres(owner=2)
        t = s.addtask(cost=1.0)
        s.addlock(t, r)
        s.prepare()
        s.start()
        assert len(s.queues[2]) == 1 and len(s.queues[0]) == 0

    def test_stealing_drains_imbalanced_queues(self):
        # all resources owned by queue 0 — workers 1..3 must steal
        s = QSched(nr_queues=4, reown=True)
        for i in range(40):
            r = s.addres(owner=0)
            t = s.addtask(cost=1.0)
            s.addlock(t, r)
        res = simulate(s, 4)
        assert res.makespan == pytest.approx(10.0)
        assert s.steals > 0

    def test_reown_migrates_ownership(self):
        s = QSched(nr_queues=2, reown=True)
        r = s.addres(owner=0)
        t = s.addtask(cost=1.0)
        s.addlock(t, r)
        s.prepare()
        s.start()
        # worker 1 steals the task; resource must now be owned by queue 1
        tid = s.gettask(1)
        assert tid == t
        assert s.resources[r].owner == 1


class TestStaticRounds:
    def test_rounds_respect_deps_and_conflicts(self):
        s, _ = fig1_graph()
        r = s.addres()
        # make H and I conflict (paper Fig 2)
        for name_tid in (7, 8):
            s.addlock(name_tid, r)
        rounds = conflict_rounds(s, nr_lanes=4)
        validate_rounds(s, rounds)

    def test_round_lane_counts(self):
        s = QSched(nr_queues=1)
        for i in range(16):
            s.addtask(cost=1.0)
        rounds = conflict_rounds(s, nr_lanes=4)
        assert len(rounds) == 1
        assert sum(len(v) for v in rounds[0].lanes.values()) == 16


class TestThreadedExecutor:
    def test_threaded_matches_sequential(self):
        s, ids = fig1_graph(nq=4)
        acc = []
        lock = threading.Lock()

        def fun(ty, d):
            with lock:
                acc.append(d)

        s.run_threaded(4, fun)
        assert sorted(acc) == sorted("ABCDEFGHIJK")

    def test_threaded_conflict_exclusion(self):
        """Conflicting tasks increment a shared counter non-atomically; with
        correct conflict handling the result is exact."""
        s = QSched(nr_queues=4, reown=False)
        r = s.addres()
        counter = {"v": 0}
        N = 60
        for i in range(N):
            t = s.addtask(data=i, cost=1.0)
            s.addlock(t, r)

        def fun(ty, d):
            v = counter["v"]
            # deliberately racy read-modify-write; the conflict must serialize
            for _ in range(50):
                pass
            counter["v"] = v + 1

        s.run_threaded(4, fun)
        assert counter["v"] == N


@st.composite
def random_dag(draw):
    n = draw(st.integers(min_value=1, max_value=40))
    nres = draw(st.integers(min_value=1, max_value=10))
    edges = []
    for j in range(1, n):
        for i in draw(st.lists(st.integers(0, j - 1), max_size=3)):
            edges.append((i, j))
    locks = [draw(st.lists(st.integers(0, nres - 1), max_size=3, unique=True))
             for _ in range(n)]
    costs = [draw(st.floats(min_value=0.1, max_value=10.0,
                            allow_nan=False)) for _ in range(n)]
    return n, nres, edges, locks, costs


@settings(max_examples=60, deadline=None)
@given(random_dag(), st.integers(min_value=1, max_value=8))
def test_property_simulation_valid_and_bounded(dag, workers):
    """For random DAGs with random conflicts: the simulator executes every
    task exactly once, respects deps+conflicts, and the makespan is bounded
    below by max(critical path, total/workers) and above by total cost."""
    n, nres, edges, locks, costs = dag
    s = QSched(nr_queues=workers)
    for r in range(nres):
        s.addres()
    for i in range(n):
        s.addtask(data=i, cost=costs[i])
    for a, b in edges:
        s.addunlock(a, b)
    for i, ls in enumerate(locks):
        for r in ls:
            s.addlock(i, r)
    res = simulate(s, workers)
    s.validate_schedule(res.timeline)
    total = sum(costs)
    cp = critical_path_length(n, [s.tasks[i].unlocks for i in range(n)], costs)
    assert res.makespan <= total + 1e-6
    assert res.makespan >= max(cp, total / workers) - 1e-6
    # rounds built from the same graph must also validate
    rounds = conflict_rounds(s, nr_lanes=workers)
    validate_rounds(s, rounds)
