"""Array-native core + ExecutionPlan lowering tests (the multi-layer
refactor): array-backed ``prepare()`` vs the reference recurrence, plan
round validity on random conflicting/hierarchical graphs, the level
shortcut vs the greedy constructor, plan caching, batch-spec dispatch, the
vectorized QR builder vs its per-call oracle, BH ``rounds`` vs
``sequential``, and the construction-API validation."""

import random

import numpy as np
import pytest

from repro.apps import barneshut as bh
from repro.apps import qr
from repro.core import (QSched, conflict_rounds, critical_path_weights,
                        lower, validate_rounds, BatchSpec, clear_plan_cache)
import repro.core.plan as plan_mod


def random_sched(rng, n_max=40, nres_max=10, hierarchical=False,
                 lock_p=0.7):
    n = rng.randint(1, n_max)
    nres = rng.randint(1, nres_max)
    s = QSched(nr_queues=rng.randint(1, 4))
    parents = []
    for r in range(nres):
        parent = rng.randrange(-1, r) if (hierarchical and r) else -1
        parents.append(parent)
        s.addres(owner=rng.randrange(-1, 4), parent=parent)

    def chain(r):
        out = {r}
        while parents[r] != -1:
            r = parents[r]
            out.add(r)
        return out

    costs = [rng.uniform(0.1, 10.0) for _ in range(n)]
    for i in range(n):
        s.addtask(data=i, cost=costs[i])
    for j in range(1, n):
        for i in rng.sample(range(j), min(j, rng.randint(0, 3))):
            s.addunlock(i, j)
    for i in range(n):
        if rng.random() < lock_p:
            taken = set()
            for r in rng.sample(range(nres), rng.randint(1, min(3, nres))):
                # a task locking both a resource and its own ancestor can
                # never acquire its lock set — skip such combinations
                if any(r in chain(q) or q in chain(r) for q in taken):
                    continue
                taken.add(r)
                s.addlock(i, r)
    return s, costs


class TestArrayPrepare:
    def test_weights_match_reference_exactly(self):
        """Vectorized Kahn + segment-max sweep must be *bitwise* equal to
        the reference recurrence from weights.py, flat and hierarchical."""
        rng = random.Random(1)
        for case in range(80):
            s, costs = random_sched(rng, hierarchical=(case % 2 == 0))
            s.prepare()
            unlocks = [s.tasks[i].unlocks for i in range(s.nr_tasks)]
            ref, order = critical_path_weights(s.nr_tasks, unlocks, costs)
            got = [t.weight for t in s.tasks]
            assert got == ref, f"case {case}: weights diverge"
            # topo_order is a valid topological order
            pos = {t: i for i, t in enumerate(s.topo_order)}
            for i in range(s.nr_tasks):
                for j in unlocks[i]:
                    assert pos[i] < pos[j]

    def test_cycle_detection(self):
        s = QSched()
        a, b = s.addtask(), s.addtask()
        s.addunlock(a, b)
        s.addunlock(b, a)
        with pytest.raises(ValueError, match="cycle"):
            s.prepare()

    def test_cost_update_recomputes_weights_without_recompiling(self):
        s = QSched()
        a, b = s.addtask(cost=1.0), s.addtask(cost=2.0)
        s.addunlock(a, b)
        s.prepare()
        g = s.graph
        assert [t.weight for t in s.tasks] == [3.0, 2.0]
        s.set_costs([5.0, 2.0])
        s.prepare()
        assert s.graph is g, "structure recompiled for a pure cost change"
        assert [t.weight for t in s.tasks] == [7.0, 2.0]


class TestPlanLowering:
    def test_rounds_valid_on_random_conflicting_graphs(self):
        rng = random.Random(2)
        for case in range(40):
            s, _ = random_sched(rng, hierarchical=(case % 2 == 0))
            nr_lanes = rng.randint(1, 6)
            plan = lower(s, nr_lanes, cache=False)
            rounds = conflict_rounds(s, nr_lanes)
            validate_rounds(s, rounds)
            assert sum(len(r.tids) for r in plan.rounds) == s.nr_tasks
            # every task appears in exactly one lane of its round
            for rnd in plan.rounds:
                lane_tasks = [t for lane in rnd.lanes for t in lane]
                assert sorted(lane_tasks) == sorted(rnd.tids)

    def test_level_shortcut_matches_greedy(self):
        """On conflict-free-by-level graphs (QR) the level shortcut must
        reproduce the general greedy constructor exactly."""
        s, _ = qr.make_qr_graph(10, 10)
        s.prepare()
        p_fast = plan_mod._lower(s, 8, None, "h")
        assert p_fast.stats["level_shortcut"]
        orig = plan_mod._level_rounds
        plan_mod._level_rounds = lambda *a, **k: None
        try:
            p_slow = plan_mod._lower(s, 8, None, "h")
        finally:
            plan_mod._level_rounds = orig
        assert not p_slow.stats["level_shortcut"]
        assert [r.tids for r in p_fast.rounds] == [r.tids for r in p_slow.rounds]
        assert [r.lanes for r in p_fast.rounds] == [r.lanes for r in p_slow.rounds]
        assert [r.batches for r in p_fast.rounds] == [
            r.batches for r in p_slow.rounds]

    def test_conflicting_ready_set_falls_back(self):
        """Tasks sharing one resource must spread across rounds (greedy
        path), still passing validation."""
        s = QSched()
        r = s.addres()
        for i in range(5):
            t = s.addtask(data=i, cost=1.0)
            s.addlock(t, r)
        plan = lower(s, 2, cache=False)
        assert not plan.stats["level_shortcut"]
        assert plan.nr_rounds == 5
        validate_rounds(s, conflict_rounds(s, 2))

    def test_hierarchy_blocks_round_sharing(self):
        s = QSched()
        root = s.addres()
        kid = s.addres(parent=root)
        tp = s.addtask(cost=1.0)
        s.addlock(tp, root)
        tc = s.addtask(cost=1.0)
        s.addlock(tc, kid)
        plan = lower(s, 2, cache=False)
        assert plan.nr_rounds == 2
        validate_rounds(s, conflict_rounds(s, 2))

    def test_max_tasks_per_round_cap(self):
        s = QSched()
        for i in range(10):
            s.addtask(cost=1.0)
        plan = lower(s, 2, max_tasks_per_round=3, cache=False)
        assert all(len(r.tids) <= 3 for r in plan.rounds)
        assert sum(len(r.tids) for r in plan.rounds) == 10


class TestPlanCache:
    def test_identical_structure_hits_cache(self):
        clear_plan_cache()
        s1, _ = qr.make_qr_graph(6, 6)
        s2, _ = qr.make_qr_graph(6, 6)   # rebuilt, structurally identical
        p1 = lower(s1, 4)
        p2 = lower(s2, 4)
        assert p1 is p2, "structurally identical graph must reuse the plan"

    def test_cost_change_misses_cache(self):
        clear_plan_cache()
        s1, _ = qr.make_qr_graph(6, 6)
        p1 = lower(s1, 4)
        s1.set_costs([c * 2 for c in s1._tcost])
        p2 = lower(s1, 4)
        assert p1 is not p2

    def test_type_change_misses_cache(self):
        """Same structure/costs but different task types must not share a
        plan (TypedBatch types are baked into the plan)."""
        clear_plan_cache()

        def build(swap):
            s = QSched()
            a = s.addtask(type=1 if swap else 0, cost=1.0)
            b = s.addtask(type=0 if swap else 1, cost=1.0)
            s.addunlock(a, b)
            return s
        p1 = lower(build(False), 2)
        p2 = lower(build(True), 2)
        assert p1 is not p2
        assert [tb.ttype for r in p2.rounds for tb in r.batches] == [1, 0]

    def test_lane_count_in_key(self):
        clear_plan_cache()
        s, _ = qr.make_qr_graph(6, 6)
        assert lower(s, 4) is not lower(s, 8)

    def test_cached_plan_executes_on_rebuilt_sched(self):
        clear_plan_cache()
        s1, _ = qr.make_qr_graph(5, 5)
        lower(s1, 2)
        s2, _ = qr.make_qr_graph(5, 5)
        plan = lower(s2, 2)
        seen = []
        registry = {tt: BatchSpec(
            run_one=lambda tid, d, tt=tt: seen.append((tt, d)))
            for tt in range(4)}
        plan.execute(s2, registry)
        assert len(seen) == s2.nr_tasks


class TestBatchDispatch:
    def test_run_batch_used_above_min_batch(self):
        s = QSched()
        for i in range(6):
            s.addtask(type=7, data=i, cost=1.0)
        ones, batches = [], []
        reg = {7: BatchSpec(run_one=lambda tid, d: ones.append(d),
                            run_batch=lambda tids, ds: batches.append(ds),
                            min_batch=2)}
        lower(s, 2, cache=False).execute(s, reg)
        assert batches == [[0, 1, 2, 3, 4, 5]] and not ones

    def test_singletons_use_run_one(self):
        s = QSched()
        prev = None
        for i in range(3):          # a chain: one task per round
            t = s.addtask(type=7, data=i, cost=1.0)
            if prev is not None:
                s.addunlock(prev, t)
            prev = t
        ones, batches = [], []
        reg = {7: BatchSpec(run_one=lambda tid, d: ones.append(d),
                            run_batch=lambda tids, ds: batches.append(ds))}
        lower(s, 1, cache=False).execute(s, reg)
        assert ones == [0, 1, 2] and not batches

    def test_virtual_tasks_skipped(self):
        from repro.core import FLAG_VIRTUAL
        s = QSched()
        s.addtask(type=0, data="a")
        s.addtask(type=0, data="v", flags=FLAG_VIRTUAL)
        seen = []
        reg = {0: BatchSpec(run_one=lambda tid, d: seen.append(d))}
        lower(s, 1, cache=False).execute(s, reg)
        assert seen == ["a"]

    def test_missing_spec_raises(self):
        s = QSched()
        s.addtask(type=3, data=0)
        with pytest.raises(KeyError, match="task type 3"):
            lower(s, 1, cache=False).execute(s, {})

    def test_all_virtual_type_needs_no_spec(self):
        from repro.core import FLAG_VIRTUAL
        s = QSched()
        a = s.addtask(type=0, data="a")
        v = s.addtask(type=9, data="v", flags=FLAG_VIRTUAL)
        s.addunlock(a, v)
        seen = []
        reg = {0: BatchSpec(run_one=lambda tid, d: seen.append(d))}
        lower(s, 1, cache=False).execute(s, reg)   # no spec for type 9
        assert seen == ["a"]


class TestVectorizedQRBuilder:
    @pytest.mark.parametrize("mt,nt", [(1, 1), (4, 4), (8, 8), (5, 3), (3, 5)])
    def test_streams_identical_to_loop_oracle(self, mt, nt):
        a, _ = qr.make_qr_graph(mt, nt)
        b, _ = qr.make_qr_graph_loop(mt, nt)
        assert a._ttype == b._ttype
        assert a._tdata == b._tdata
        assert a._tcost == b._tcost
        for x, y in ((a._deps, b._deps), (a._locks, b._locks),
                     (a._uses, b._uses)):
            xa, xb = x.arrays()
            ya, yb = y.arrays()
            assert xa.tolist() == ya.tolist()
            assert xb.tolist() == yb.tolist()
        assert a._res_parent == b._res_parent
        assert a._res_owner == b._res_owner


class TestBHRoundsMode:
    # NOTE: cross-mode numerical equivalence moved to the backend matrix
    # in tests/test_backends.py (TestMatrixBarnesHut).
    def test_bh_plan_rounds_validate(self):
        rng = np.random.default_rng(4)
        x, m = rng.random((800, 3)), rng.random(800) + 0.5
        tree = bh.Octree(x, m, n_max=64)
        g = bh.build_graph(tree, n_task=256, nr_queues=4)
        validate_rounds(g.sched, conflict_rounds(g.sched, 4))


# NOTE: the pipeline plan-driver equivalence test moved to the backend
# matrix in tests/test_backends.py (TestMatrixPipeline), which asserts it
# across every registered backend including the engine.


class TestConstructionValidation:
    def test_set_costs_length_mismatch_raises(self):
        s = QSched()
        s.addtask()
        s.addtask()
        with pytest.raises(ValueError, match="2 tasks"):
            s.set_costs([1.0])
        with pytest.raises(ValueError, match="3 costs"):
            s.set_costs([1.0, 2.0, 3.0])
        s.set_costs([4.0, 5.0])          # matching length still works
        assert [t.cost for t in s.tasks] == [4.0, 5.0]

    def test_addlock_validates_ids(self):
        s = QSched()
        t = s.addtask()
        r = s.addres()
        with pytest.raises(ValueError, match="task id"):
            s.addlock(t + 1, r)
        with pytest.raises(ValueError, match="resource id"):
            s.addlock(t, r + 1)
        with pytest.raises(ValueError, match="resource id"):
            s.addlock(t, -1)

    def test_adduse_validates_ids(self):
        s = QSched()
        t = s.addtask()
        s.addres()
        with pytest.raises(ValueError, match="task id"):
            s.adduse(5, 0)
        with pytest.raises(ValueError, match="resource id"):
            s.adduse(t, 9)

    def test_addunlock_validates_ids(self):
        s = QSched()
        a, b = s.addtask(), s.addtask()
        with pytest.raises(ValueError, match="task id"):
            s.addunlock(a, 7)
        with pytest.raises(ValueError, match="task id"):
            s.addunlock(-3, b)
        with pytest.raises(ValueError, match="itself"):
            s.addunlock(a, a)

    def test_bulk_apis_validate(self):
        s = QSched()
        s.addtask()
        s.addtask()
        s.addres()
        with pytest.raises(ValueError, match="out of range"):
            s.addunlocks([0], [5])
        with pytest.raises(ValueError, match="itself"):
            s.addunlocks([1], [1])
        with pytest.raises(ValueError, match="out of range"):
            s.addlocks([0], [3])
        with pytest.raises(ValueError, match="out of range"):
            s.adduses([7], [0])
        with pytest.raises(ValueError, match="mismatch"):
            s.addunlocks([0, 1], [1])
        with pytest.raises(ValueError, match="flags=1"):
            s.addtasks([0, 0], [1.0, 1.0], [None, None], flags=[0])
