"""Backend registry + the cross-mode equivalence matrix (ISSUE 4).

The paper's claim is ONE scheduler core serving heterogeneous workloads
with no per-workload executor code; `core/backends.py` is that claim at
the dispatch layer.  These tests pin it down three ways:

* registry semantics — lookup, capability flags, ``supports()`` probing,
  ``BackendUnsupported`` on capability mismatch;
* the equivalence matrix — every registered backend × all three task
  families (QR bitwise against the sequential oracle, BH and the pipeline
  within the established reassociation tolerances), replacing the
  per-app mode tests that used to be scattered over test_qr/test_plan/
  test_engine;
* the pipeline engine acceptance — a whole pipelined value-and-grad step
  as one jitted dispatch, matching ``jax.grad`` of the unpipelined loss;
* simulator validation (ROADMAP slice) — measured engine round times
  replayed through the discrete-event model predict the fused execute
  time within a stated bound.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.apps import barneshut as bh
from repro.apps import qr
from repro.core import (Backend, BackendUnsupported, BatchSpec, EngineHooks,
                        QSched, available_backends, get_backend, lower,
                        register_backend, replay_item_times,
                        replay_round_times, run_plan)
from repro.pipeline import synthesize_schedule
from repro.pipeline.exec import (dense_stage, mse_loss,
                                 pipelined_value_and_grad,
                                 pipelined_value_and_grad_plan)

ALL_MODES = ("sequential", "threaded", "rounds", "engine")


class TestRegistry:
    def test_all_modes_registered(self):
        assert set(ALL_MODES) <= set(available_backends())

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown execution backend"):
            get_backend("warp-drive")

    def test_capability_flags(self):
        assert get_backend("rounds").needs_plan
        assert get_backend("engine").needs_plan
        assert get_backend("engine").device_resident
        assert get_backend("threaded").concurrent
        assert not get_backend("sequential").concurrent
        assert not get_backend("sequential").needs_plan

    def test_register_and_dispatch_custom_backend(self):
        class Recording(Backend):
            name = "recording"
            needs_plan = True

            def run(self, sched, plan, registry, *, nr_workers=1,
                    engine=None):
                self.seen = [t for rnd in plan.rounds for t in rnd.tids]

        be = register_backend(Recording())
        try:
            s = QSched()
            for i in range(4):
                s.addtask(type=0, data=i)
            run_plan(s, {0: BatchSpec(run_one=lambda tid, d: None)},
                     "recording")
            assert sorted(be.seen) == [0, 1, 2, 3]
        finally:
            import repro.core.backends as backends_mod
            del backends_mod._BACKENDS["recording"]

    def test_engine_supports_requires_hooks_and_encoders(self):
        s = QSched()
        s.addtask(type=0, data=0)
        plan = lower(s, 1, cache=False)
        be = get_backend("engine")
        no_enc = {0: BatchSpec(run_one=lambda tid, d: None)}
        enc = {0: BatchSpec(run_one=lambda tid, d: None,
                            encode=lambda tid, d: [(0, 0)])}
        hooks = EngineHooks(arg_width=1, round_fn=None,
                            statics=tuple, buffers=tuple,
                            writeback=lambda out: None)
        assert not be.supports(plan, s, enc, None)       # no family hooks
        assert not be.supports(plan, s, no_enc, hooks)   # no encoder
        assert be.supports(plan, s, enc, hooks)

    def test_run_plan_raises_backend_unsupported(self):
        s = QSched()
        s.addtask(type=0, data=0)
        with pytest.raises(BackendUnsupported):
            run_plan(s, {0: BatchSpec(run_one=lambda tid, d: None)},
                     "engine")

    def test_plan_run_dispatches_through_registry(self):
        s = QSched()
        for i in range(3):
            s.addtask(type=0, data=i)
        seen = []
        plan = lower(s, 2, cache=False)
        plan.run(s, {0: BatchSpec(run_one=lambda tid, d: seen.append(d))},
                 backend="rounds")
        assert sorted(seen) == [0, 1, 2]

    def test_sequential_backend_missing_spec_raises(self):
        s = QSched()
        s.addtask(type=3, data=0)
        with pytest.raises(KeyError, match="task type 3"):
            run_plan(s, {}, "sequential")


# ---------------------------------------------------------------------------
# the equivalence matrix: every backend × every task family
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qr_case():
    a = jnp.asarray(np.random.default_rng(0).standard_normal((96, 96)),
                    jnp.float32)
    oracle, _ = qr.run_qr(a, tile=32, mode="sequential", backend="pallas")
    return a, np.asarray(oracle)


class TestMatrixQR:
    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_matches_sequential_bitwise(self, qr_case, mode):
        """All backends share the same value-level tile math and a fully
        deterministic dependency order, so R must be BITWISE equal."""
        a, oracle = qr_case
        r, _ = qr.run_qr(a, tile=32, mode=mode, backend="pallas",
                         nr_queues=4)
        np.testing.assert_array_equal(np.asarray(r), oracle)

    def test_oracle_is_valid_r(self, qr_case):
        a, r = qr_case
        rhs = np.asarray(a).T @ np.asarray(a)
        assert np.abs(np.tril(r, -1)).max() < 1e-4
        assert np.abs(r.T @ r - rhs).max() / np.abs(rhs).max() < 1e-4


@pytest.fixture(scope="module")
def bh_case():
    rng = np.random.default_rng(3)
    x, m = rng.random((1200, 3)), rng.random(1200) + 0.5
    acc, _, _ = bh.solve(x, m, n_max=32, n_task=128, backend="ref",
                         mode="sequential")
    return x, m, np.asarray(acc)


def _bh_rel_err(a, oracle):
    num = np.linalg.norm(np.asarray(a) - oracle, axis=0)
    den = np.linalg.norm(oracle, axis=0)
    return (num / np.maximum(den, 1e-12)).max()


class TestMatrixBarnesHut:
    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_matches_sequential(self, bh_case, mode):
        """Accumulation order differs per backend only by float
        reassociation — ≤1e-4 relative (the established rounds-mode
        tolerance).  The concurrent backend accumulates in-place on a
        shared numpy buffer where the hierarchical resource locks are the
        only thing preventing lost updates."""
        x, m, oracle = bh_case
        tree = bh.Octree(x, m, n_max=32)
        g = bh.build_graph(tree, n_task=128, nr_queues=4)
        accumulate = "numpy" if get_backend(mode).concurrent else "jnp"
        st = bh.BHState(g, backend="ref", accumulate=accumulate)
        st.run(mode=mode, nr_workers=4)
        assert _bh_rel_err(st.result(), oracle) < 1e-4

    def test_engine_requires_device_accumulation(self, bh_case):
        x, m, _ = bh_case
        tree = bh.Octree(x, m, n_max=32)
        g = bh.build_graph(tree, n_task=128, nr_queues=4)
        st = bh.BHState(g, backend="ref", accumulate="numpy")
        with pytest.raises(AssertionError, match="accumulate='jnp'"):
            st.run(mode="engine")


@pytest.fixture(scope="module")
def pipe_case():
    S, M, Bt, D = 3, 6, 4, 8
    key = jax.random.PRNGKey(2)
    params = [{"w": jax.random.normal(jax.random.fold_in(key, k),
                                      (D, D)) * 0.3,
               "b": jnp.zeros((D,))} for k in range(S)]
    micro = [{"x": jax.random.normal(jax.random.fold_in(key, 10 + m),
                                     (Bt, D)),
              "y": jax.random.normal(jax.random.fold_in(key, 50 + m),
                                     (Bt, D))} for m in range(M)]

    def monolithic(ps):
        total = 0.0
        for mb in micro:
            h = mb["x"]
            for p in ps:
                h = dense_stage(p, h)
            total = total + mse_loss(h, mb)
        return total / M

    loss, grads = jax.value_and_grad(monolithic)(params)
    return S, M, params, micro, float(loss), grads


class TestMatrixPipeline:
    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_value_and_grad_equals_monolithic(self, pipe_case, mode):
        """Acceptance gate: every backend — including the single-dispatch
        engine — reproduces ``jax.grad`` of the unpipelined loss within
        the established plan-mode tolerance."""
        S, M, params, micro, loss_m, grads_m = pipe_case
        loss_p, grads_p = pipelined_value_and_grad_plan(
            [dense_stage] * S, mse_loss, params, micro, mode=mode)
        assert abs(float(loss_p) - loss_m) < 1e-6
        for gp, gm in zip(grads_p, grads_m):
            for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gm)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)

    def test_engine_rejects_non_canonical_family(self, pipe_case):
        S, M, params, micro, _, _ = pipe_case

        def other_stage(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        with pytest.raises(BackendUnsupported, match="canonical dense"):
            pipelined_value_and_grad_plan(
                [other_stage] * S, mse_loss, params, micro, mode="engine")

    def test_engine_rejects_mismatched_param_count(self, pipe_case):
        """Fewer params than stages must fail the capability probe, not
        read out of bounds in the kernel."""
        S, M, params, micro, _, _ = pipe_case
        with pytest.raises(BackendUnsupported, match="canonical dense"):
            pipelined_value_and_grad_plan(
                [dense_stage] * S, mse_loss, params[:-1], micro,
                mode="engine")

    def test_engine_is_single_dispatch(self, pipe_case):
        """The dispatch-count claim: the host rounds path issues one call
        per task body while the engine issues exactly one jitted call for
        the whole value-and-grad step."""
        from repro.pipeline.exec import _PipeRunner
        from repro.pipeline import lower_pipeline_plan
        S, M, params, micro, _, _ = pipe_case
        runner = _PipeRunner([dense_stage] * S, mse_loss, params, micro)
        sched, _, plan = lower_pipeline_plan(S, M, per_stage_window=True)
        host = engine.count_host_dispatches(plan, sched, runner.registry())
        assert host >= 5 * engine.ENGINE_DISPATCHES_PER_PLAN
        assert engine.ENGINE_DISPATCHES_PER_PLAN == 1

    def test_unknown_event_kind_raises(self, pipe_case):
        """Satellite regression: unknown schedule event kinds used to be
        silently skipped; they must now raise."""
        S, M, params, micro, _, _ = pipe_case
        ps = synthesize_schedule(S, M)
        ps.lanes[0].insert(0, ("Z", 0, 0, -1.0, -0.5))
        with pytest.raises(ValueError, match="unknown pipeline event"):
            pipelined_value_and_grad(
                [dense_stage] * S, mse_loss, params, micro, ps)

    def test_update_events_are_noop_for_caller(self, pipe_case):
        """The U events run (no exception) and leave the returned grads
        unapplied — applying the optimizer is the documented caller
        contract."""
        S, M, params, micro, loss_m, _ = pipe_case
        ps = synthesize_schedule(S, M)
        assert any(kind == "U" for lane in ps.lanes
                   for kind, *_ in lane)
        loss_p, _ = pipelined_value_and_grad(
            [dense_stage] * S, mse_loss, params, micro, ps)
        assert abs(float(loss_p) - loss_m) < 1e-6


# ---------------------------------------------------------------------------
# simulator validation (ROADMAP slice): replay measured engine round times
# ---------------------------------------------------------------------------

class TestSimulatorReplay:
    def test_replayed_makespan_predicts_fused_execute(self):
        """Measure per-round engine times, replay them through the
        discrete-event simulator, and compare the predicted makespan with
        the measured single-dispatch execute time.  Stated bound: the
        additive round model must predict the fused wall time within a
        factor of 5 either way (interpret-mode dispatch overhead differs
        between per-round and in-loop launches, and CI machines jitter —
        both measurements take the best over 3 passes; the *model*
        consistency — replayed 1-worker makespan == Σ measured round
        times — is exact)."""
        a = jnp.asarray(np.random.default_rng(0).standard_normal((96, 96)),
                        jnp.float32)
        tiles, mt, nt = qr._split_tiles(a, 32)
        sched, _ = qr.make_qr_graph(mt, nt, nr_queues=4)
        plan = lower(sched, 4)
        state = qr._TileState(dict(tiles), "pallas")
        tables = engine.lower_tables(
            plan, sched, state.batch_registry(),
            arg_width=engine.QR_ARG_WIDTH, row_access=engine.qr_row_access)
        stack = jnp.stack([tiles[i, j]
                           for j in range(nt) for i in range(mt)])
        fn = engine.qr_round_fn()
        round_times = None
        for _ in range(3):      # elementwise best-of-3 absorbs CI jitter
            timings = engine.measure_round_times(
                tables, fn, (), (stack, jnp.zeros_like(stack)))
            times = timings.round_s
            round_times = (times if round_times is None
                           else [min(a_, b_)
                                 for a_, b_ in zip(round_times, times)])
        assert len(round_times) == plan.nr_rounds

        # the model itself is additive and exact
        res = replay_round_times(sched, plan, round_times, nr_workers=1)
        assert res.makespan == pytest.approx(sum(round_times), rel=1e-9)

        # measured fused execute (compile warmed up, best of 3)
        engine.execute_plan(tables, fn, (),
                            (stack, jnp.zeros_like(stack)), donate=False)
        measured = float("inf")
        for _ in range(3):
            bufs = (stack + 0.0, jnp.zeros_like(stack))
            t0 = time.perf_counter()
            out = engine.execute_plan(tables, fn, (), bufs, donate=False)
            jax.block_until_ready(out)
            measured = min(measured, time.perf_counter() - t0)
        ratio = res.makespan / measured
        assert 0.2 <= ratio <= 5.0, (
            f"predicted {res.makespan:.4f}s vs measured {measured:.4f}s "
            f"(ratio {ratio:.2f})")

    def test_per_item_times_replay_lane_parallel_makespans(self):
        """Per-item measurements (``measure_round_times(per_item=True)``)
        give every task its own measured cost, so ``replay_item_times``
        can predict *parallel* makespans (ROADMAP: simulator validation
        beyond one worker).  Model consistency bounds: the 1-worker replay
        is exactly Σ item times; a 4-worker replay can be no better than
        the critical path and no worse than serial."""
        a = jnp.asarray(np.random.default_rng(1).standard_normal((96, 96)),
                        jnp.float32)
        tiles, mt, nt = qr._split_tiles(a, 32)
        sched, _ = qr.make_qr_graph(mt, nt, nr_queues=4)
        plan = lower(sched, 4)
        state = qr._TileState(dict(tiles), "pallas")
        tables = engine.lower_tables(
            plan, sched, state.batch_registry(),
            arg_width=engine.QR_ARG_WIDTH, row_access=engine.qr_row_access)
        stack = jnp.stack([tiles[i, j]
                           for j in range(nt) for i in range(mt)])
        timings = engine.measure_round_times(
            tables, engine.qr_round_fn(), (),
            (stack, jnp.zeros_like(stack)), per_item=True)
        assert timings.item_s is not None
        assert len(timings.item_s) == tables.nr_items
        assert (timings.item_s > 0).all()

        serial = replay_item_times(sched, tables.tids, timings.item_s,
                                   nr_workers=1)
        assert serial.makespan == pytest.approx(float(timings.item_s.sum()),
                                                rel=1e-9)
        par = replay_item_times(sched, tables.tids, timings.item_s,
                                nr_workers=4)
        assert par.makespan <= serial.makespan + 1e-12
        # per-task measured costs: the longest task bounds any makespan
        per_task = np.zeros(sched.nr_tasks)
        np.add.at(per_task, np.asarray(tables.tids), timings.item_s)
        assert par.makespan >= per_task.max() - 1e-12

    def test_replay_item_times_validates_lengths(self):
        s, _ = qr.make_qr_graph(3, 3)
        with pytest.raises(ValueError, match="item times"):
            replay_item_times(s, [0, 1], [0.1])
        with pytest.raises(ValueError, match="out of range"):
            replay_item_times(s, [s.nr_tasks], [0.1])

    def test_replay_restores_costs(self):
        s, _ = qr.make_qr_graph(4, 4)
        plan = lower(s, 2)
        before = list(s._tcost)
        replay_round_times(s, plan, [0.5] * plan.nr_rounds, nr_workers=2)
        assert list(s._tcost) == before

    def test_replay_length_mismatch_raises(self):
        s, _ = qr.make_qr_graph(3, 3)
        plan = lower(s, 2)
        with pytest.raises(ValueError, match="round times"):
            replay_round_times(s, plan, [0.1])
