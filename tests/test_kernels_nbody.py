"""N-body kernel validation: Pallas (interpret) vs jnp oracle, shape/dtype
sweep + properties (paper §4.2 kernels)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels.nbody import kernel, ops, ref


def cloud(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((3, n)), dtype=jnp.float32)
    m = jnp.asarray(rng.random((n,)) + 0.1, dtype=jnp.float32)
    return x, m


@pytest.mark.parametrize("ni,nj", [(1, 1), (7, 5), (64, 33), (128, 128),
                                   (200, 300), (256, 1000)])
def test_pair_matches_ref(ni, nj):
    xi, _ = cloud(ni, ni)
    xj, mj = cloud(nj, nj + 1)
    got = ops.acc_pair(xi, xj, mj, backend="pallas")
    want = ref.acc_pair_ref(xi, xj, mj)
    assert got.shape == (3, ni)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("n", [2, 16, 100, 128, 257, 512])
def test_self_matches_ref(n):
    x, m = cloud(n, n + 7)
    got = ops.acc_self(x, m, backend="pallas")
    want = ref.acc_self_ref(x, m)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-5)


def test_self_excludes_diagonal():
    """A single particle feels no force from itself."""
    x = jnp.zeros((3, 1), jnp.float32)
    m = jnp.ones((1,), jnp.float32)
    assert float(jnp.abs(ops.acc_self(x, m, backend="pallas")).max()) == 0.0


def test_newton_third_law():
    """Total momentum change of a closed system vanishes:
    sum_i m_i a_i = 0 for the exact pairwise force."""
    x, m = cloud(96, 3)
    acc = ops.acc_self(x, m, backend="pallas")
    p = np.asarray(acc) @ np.asarray(m)
    assert np.abs(p).max() < 1e-2 * float(jnp.abs(acc).max() * jnp.sum(m))


@settings(max_examples=20, deadline=None)
@given(ni=st.integers(1, 64), nj=st.integers(1, 64), seed=st.integers(0, 999),
       eps=st.floats(1e-4, 1e-1))
def test_property_pair_kernel(ni, nj, seed, eps):
    xi, _ = cloud(ni, seed)
    xj, mj = cloud(nj, seed + 1)
    got = ops.acc_pair(xi, xj, mj, eps=eps, backend="pallas")
    want = ref.acc_pair_ref(xi, xj, mj, eps=eps)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 40), seed=st.integers(0, 999))
def test_property_superposition(n, seed):
    """Splitting the sources into two halves and summing equals one call —
    force superposition (the invariant the task decomposition relies on)."""
    xi, _ = cloud(8, seed + 2)
    xj, mj = cloud(n, seed)
    k = n // 2
    whole = ops.acc_pair(xi, xj, mj, backend="pallas")
    parts = (ops.acc_pair(xi, xj[:, :k], mj[:k], backend="pallas")
             + ops.acc_pair(xi, xj[:, k:], mj[k:], backend="pallas"))
    assert_allclose(np.asarray(whole), np.asarray(parts), rtol=1e-3, atol=2e-5)
